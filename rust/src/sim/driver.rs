//! Simulation engine: edge stream → (REC merge) → cache → LiGNN → DRAM.
//!
//! The engine is phase-based: callers push [`Phase`]s (forward /
//! backward edge drives, aggregation and mask write-backs) through a
//! [`SimEngine`], `drain` at sync points, and `finish` into [`Metrics`].
//! One shared edge-drive routine serves both the merged (`RecMerger`)
//! and plain read paths, so every phase — forward or backward, any
//! layer — runs the identical pipeline.
//!
//! [`run_sim`] remains the one-call entry point: it composes the phase
//! schedule implied by the config (`layers` × `epochs`, optional
//! backward, `sampler`) and reproduces the pre-engine single-layer
//! driver bit-for-bit when `layers == epochs == 1` under full-batch
//! sampling. Multi-layer runs read layer-2+ intermediates from the
//! write-back region at `hidden` elements per vertex, making the paper's
//! "layer 1 dominates" premise a measured result
//! (`Metrics::layer_reads`); the region is double-buffered per layer so
//! a layer's intermediate reads never alias its own write-backs. `exec =
//! max(memory, compute)` since GCNTrain overlaps its datapaths.
//!
//! Mini-batch sampling: each epoch drives the [`EpochSubgraph`] the
//! config's [`Sampler`](crate::sample::Sampler) produces for that epoch
//! index — the forward edge stream, its dropout mask and the backward
//! transpose all follow the sampled subset. [`run_sampled_sim`] accepts
//! an explicit sampler for policies outside `SamplerKind`.

use crate::accel::{EngineParams, Interleaver};
use crate::cache::LruCache;
use crate::config::SimConfig;
use crate::dram::energy::EnergyReport;
use crate::dram::{DramModel, DramReq};
use crate::graph::CsrGraph;
use crate::lignn::{AddressCalc, Burst, Criteria, Edge, LignnUnit, RecMerger, UnitStats};
use crate::sample::{EpochSubgraph, Sampler};
use crate::telemetry::{DramDelta, DramSnapshot, Recorder, SpanEvent, SpanKind, SpatialProfiler};

use super::frfcfs::{FrFcfs, DEFAULT_DEPTH};
use super::metrics::Metrics;
use super::trace::TraceWriter;

/// Classification state per feature-read instance (`Burst::seq`).
#[derive(Clone, Copy, PartialEq, Eq)]
enum Served {
    None,
    Merged,
    Opened,
}

/// One step of the engine's lifecycle. Callers compose epochs from
/// these; [`run_sim`] is the canonical composition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Drive the aggregation edge stream for `layer` (0-based). Layer 0
    /// reads the raw feature matrix; layers ≥ 1 read the previous
    /// layer's intermediates from the write-back region.
    Forward { layer: usize },
    /// Drive the transposed edge stream (gradient aggregation,
    /// Â^T·∂L/∂H) through the same unit — the forward mask persists, so
    /// no fresh dropout decisions are made (§4.3).
    Backward,
    /// Aggregation write-back: one output feature per vertex, streamed
    /// sequentially into a disjoint region (regular, high row locality).
    WriteBack,
    /// §4.3's dropout-mask write-back (1 bit per element, sequential).
    MaskWriteBack,
}

/// The schedule step a [`PhaseCursor`] points at — what the engine
/// would execute *next* if the boundary's hook declines to preempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NextStep {
    Sample,
    Forward,
    Backward,
    WriteBack,
    MaskWriteBack,
    /// Trailing boundary fired once after `finish` (final request-log
    /// chunk only; a `true` return here has nothing left to preempt).
    Finish,
}

/// Checkpoint of the canonical schedule's position, handed to the
/// phase-boundary hook. The engine's own state (double-buffer cursor,
/// FR-FCFS window, caches, units) stays live on the worker's stack
/// while the hook runs — a preempting job executes *nested*, so resume
/// is a return and metrics are conserved by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PhaseCursor {
    pub epoch: u32,
    pub layer: usize,
    pub next: NextStep,
}

/// Phase-boundary hook: receives the schedule cursor plus the DRAM
/// request-log chunk accumulated since the previous boundary (empty
/// unless [`SimEngine::enable_request_log`] was called — QoS shared
/// mode feeds these chunks into the shared device). Return `true` iff
/// the boundary actually preempted (ran other work before returning);
/// the engine then records a zero-width `preempt` span marker.
pub type PhaseHook<'h> = dyn FnMut(PhaseCursor, Vec<DramReq>) -> bool + 'h;

/// Decorrelates the per-layer dropout streams without touching the
/// layer-0 stream (which must stay at `cfg.seed` for reproducibility).
const LAYER_SEED_STRIDE: u64 = 0x9E37_79B9_7F4A_7C15;

fn mark(served: &mut Vec<Served>, base: usize, seq: u32, activated: bool) {
    let idx = base + seq as usize - 1;
    if idx >= served.len() {
        served.resize(idx + 1, Served::None);
    }
    if activated {
        served[idx] = Served::Opened;
    } else if served[idx] == Served::None {
        served[idx] = Served::Merged;
    }
}

/// Where combination outputs land (and layer-2+ aggregations read from):
/// halfway up the address space, offset by the feature base so both
/// sites of the engine agree byte-for-byte. The region is
/// double-buffered (`buf` ∈ {0, 1}, a quarter-capacity stride): layer
/// `l` writes buffer `l % 2` while layer `l + 1` reads buffer `l % 2` —
/// so a layer's intermediate reads never alias its own write-backs.
/// Buffer 0 is the legacy single-buffer address, keeping single-layer
/// runs bit-identical.
fn intermediate_base(cfg: &SimConfig, dram: &DramModel, buf: usize) -> u64 {
    let cap = dram.mapping().capacity_bytes();
    cfg.feat_base + (cap >> 1) + if buf & 1 == 1 { cap >> 2 } else { 0 }
}

/// A telemetry span the engine has opened but not yet closed: the next
/// phase boundary (or `finish`) closes it against a fresh snapshot.
struct OpenSpan {
    kind: SpanKind,
    epoch: u32,
    start_cycle: u64,
    start: DramSnapshot,
}

fn merge_stats(into: &mut UnitStats, s: &UnitStats) {
    into.features_in += s.features_in;
    into.total_elems += s.total_elems;
    into.desired_elems += s.desired_elems;
    into.bursts_in += s.bursts_in;
    into.bursts_filter_dropped += s.bursts_filter_dropped;
    into.bursts_row_dropped += s.bursts_row_dropped;
    into.bursts_kept += s.bursts_kept;
}

/// Reusable phase-based simulation engine. Construct once per run, push
/// phases, `drain` at layer/epoch sync points, then `finish`.
pub struct SimEngine<'a> {
    cfg: &'a SimConfig,
    dram: DramModel,
    cache: LruCache,
    unit: LignnUnit,
    /// `Access`-way MLP interleaver for the non-LGT paths (LG-A/B); the
    /// LGT/REC variants issue in their own locality order instead.
    interleaver: Option<Interleaver>,
    /// Memory-controller scheduling window (part of the platform — applies
    /// to every variant).
    sched: FrFcfs,
    /// Optional DRAM burst trace capture.
    trace: Option<TraceWriter>,
    out: Vec<Burst>,
    served: Vec<Served>, // indexed by seq_base + seq - 1
    feat_hit: u64,
    /// Layer whose unit is live.
    current_layer: usize,
    /// `served` index offset of the live unit (sum of retired units'
    /// `features_in`).
    seq_base: usize,
    /// Accumulated stats of retired (earlier-layer) units.
    retired: UnitStats,
    /// Units created after the initial one (decorrelates layer seeds).
    unit_swaps: u64,
    /// DRAM read bursts credited per forward layer (the backward phase
    /// accumulates into `backward_reads` instead, so the per-layer
    /// numbers stay a clean forward-aggregation comparison).
    layer_reads: Vec<u64>,
    /// DRAM read bursts credited to backward (gradient) drives.
    backward_reads: u64,
    /// Reads since `reads_mark` go to `backward_reads` when set.
    crediting_backward: bool,
    reads_mark: u64,
    /// Feature instances already covered by a mask write-back.
    mask_mark: u64,
    /// Engine provisioning used for per-drive compute accounting.
    engine: EngineParams,
    /// Compute time accumulated per drive — each forward/backward phase
    /// is charged for the graph it actually drove, so sampled epochs
    /// cost their subgraph, not the full graph.
    compute_ns: f64,
    /// Edges driven by layer-0 forward phases (the per-epoch (sub)graph
    /// size, summed over epochs).
    sampled_edges: u64,
    /// Sampling-policy label reported in [`Metrics::sampler`].
    sampler_label: String,
    /// Telemetry sink, attached via [`set_recorder`](Self::set_recorder)
    /// only when enabled — the hot path pays a single `None` branch per
    /// *phase*, never per burst, and the recorder only ever reads the
    /// public DRAM counters (so recorded runs stay bit-identical).
    rec: Option<&'a mut dyn Recorder>,
    /// Span currently accumulating (closed by the next boundary).
    open_span: Option<OpenSpan>,
    /// Epoch stamp applied to spans opened from here on.
    epoch: u32,
    /// Tenant stamp applied to every recorded span (0 outside QoS
    /// shared mode).
    span_tenant: u32,
}

impl<'a> SimEngine<'a> {
    pub fn new(cfg: &'a SimConfig) -> SimEngine<'a> {
        cfg.validate().expect("invalid SimConfig");
        // Channel-partitioned runs get a device whose mapping can only
        // express the tenant's subset; the default is the full device.
        let dram = cfg.build_dram();
        let sched = FrFcfs::new(dram.config().channels, DEFAULT_DEPTH);
        let unit = Self::build_unit(cfg, &dram, 0, cfg.seed);
        SimEngine {
            cfg,
            dram,
            cache: LruCache::new(cfg.capacity),
            unit,
            interleaver: cfg.variant.interleaves().then(|| Interleaver::new(cfg.access)),
            sched,
            trace: cfg.trace_path.as_ref().map(|p| {
                TraceWriter::create(std::path::Path::new(p)).expect("creating trace file")
            }),
            // Grows to the run's working set on first use, or arrives
            // pre-grown through `recycle_buffer`.
            out: Vec::new(),
            served: Vec::new(),
            feat_hit: 0,
            current_layer: 0,
            seq_base: 0,
            retired: UnitStats::default(),
            unit_swaps: 0,
            layer_reads: vec![0; cfg.layers],
            backward_reads: 0,
            crediting_backward: false,
            reads_mark: 0,
            mask_mark: 0,
            engine: EngineParams::default(),
            compute_ns: 0.0,
            sampled_edges: 0,
            sampler_label: cfg.sampler_label(),
            rec: None,
            open_span: None,
            epoch: 0,
            span_tenant: 0,
        }
    }

    /// The config this engine was built from (the `'a` borrow, so
    /// callers can hold it across mutating engine calls — the sharded
    /// schedule in `reorder::shard` composes phases from outside this
    /// module).
    pub fn config(&self) -> &'a SimConfig {
        self.cfg
    }

    /// Attach a telemetry recorder for this run. A disabled recorder
    /// (`enabled() == false`, e.g. [`NullRecorder`]
    /// (crate::telemetry::NullRecorder)) is not stored at all, so the
    /// disabled path is exactly the bare engine.
    pub fn set_recorder(&mut self, rec: &'a mut dyn Recorder) {
        if rec.enabled() {
            self.rec = Some(rec);
        }
    }

    /// Stamp subsequently opened spans with `epoch` (the canonical
    /// schedules call this at each epoch top).
    pub fn set_epoch(&mut self, epoch: u32) {
        self.epoch = epoch;
    }

    /// Stamp every span this engine records with `tenant` — per-tenant
    /// span attribution for QoS shared-device runs.
    pub fn set_span_tenant(&mut self, tenant: u32) {
        self.span_tenant = tenant;
    }

    /// Start capturing this run's DRAM requests ([`DramReq`]) so phase
    /// boundaries can hand them to the hook in chunks (QoS shared mode
    /// replays them against the shared device).
    pub fn enable_request_log(&mut self) {
        self.dram.enable_request_log();
    }

    /// Drain the captured request chunk (empty when logging is off).
    pub fn take_request_log(&mut self) -> Vec<DramReq> {
        self.dram.take_request_log()
    }

    /// Attach a spatial DRAM profiler (top-`topk` hot-row sketch) to
    /// this engine's device — observation-only, so profiled runs stay
    /// bit-identical to bare ones (golden parity pins this).
    pub fn enable_profiler(&mut self, topk: usize) {
        self.dram.enable_profiler(topk);
    }

    /// Detach the profiler with its grids/sketch (None when off).
    pub fn take_profiler(&mut self) -> Option<Box<SpatialProfiler>> {
        self.dram.take_profiler()
    }

    /// Record that the engine was parked at this boundary by the QoS
    /// preemption path: a zero-width `preempt` marker span with an
    /// empty delta — visible in traces, invisible to every counter, so
    /// preempted runs telescope to the same totals as uninterrupted
    /// ones.
    pub fn note_preempt(&mut self) {
        let Some(rec) = self.rec.as_deref_mut() else { return };
        let cycle = self.dram.busy_until();
        rec.record_span(SpanEvent {
            kind: SpanKind::Preempt,
            epoch: self.epoch,
            tenant: self.span_tenant,
            start_cycle: cycle,
            end_cycle: cycle,
            dram: DramDelta::default(),
        });
    }

    /// Mark the start of per-epoch sampling (subgraph construction).
    /// Opens a `Sample` span; under full-batch training it closes
    /// zero-length at the first forward phase.
    pub fn note_sample(&mut self) {
        self.mark_span(SpanKind::Sample);
    }

    /// Phase boundary: close the open span against the current DRAM
    /// state and open a new one. In-flight bursts left in the scheduling
    /// window are serviced inside whichever span is open when they
    /// drain — the same "at most a scheduling window bleeds into the
    /// next bucket" semantics as `credit_reads`. Per-span deltas are
    /// consecutive differences of one counter stream, so they telescope
    /// to the run totals exactly.
    fn mark_span(&mut self, kind: SpanKind) {
        let Some(rec) = self.rec.as_deref_mut() else { return };
        let cycle = self.dram.busy_until();
        let snap = DramSnapshot::capture(&self.dram.counters);
        if let Some(open) = self.open_span.take() {
            rec.record_span(SpanEvent {
                kind: open.kind,
                epoch: open.epoch,
                tenant: self.span_tenant,
                start_cycle: open.start_cycle,
                end_cycle: cycle,
                dram: snap.delta_since(&open.start),
            });
        }
        self.open_span = Some(OpenSpan { kind, epoch: self.epoch, start_cycle: cycle, start: snap });
    }

    /// Close the trailing span (called by `finish` after the final
    /// drain, so the last phase's counters are fully settled).
    fn close_span(&mut self) {
        let Some(rec) = self.rec.as_deref_mut() else { return };
        let cycle = self.dram.busy_until();
        let snap = DramSnapshot::capture(&self.dram.counters);
        if let Some(open) = self.open_span.take() {
            rec.record_span(SpanEvent {
                kind: open.kind,
                epoch: open.epoch,
                tenant: self.span_tenant,
                start_cycle: open.start_cycle,
                end_cycle: cycle,
                dram: snap.delta_since(&open.start),
            });
        }
    }

    /// Override the reported sampling-policy label (used when a run is
    /// driven by an explicit [`Sampler`] rather than `cfg.sampler`).
    pub fn set_sampler_label(&mut self, label: impl Into<String>) {
        self.sampler_label = label.into();
    }

    /// Donate a previously used burst buffer (its capacity) to this run —
    /// the sweep runner recycles one per worker thread.
    pub fn recycle_buffer(&mut self, buf: &mut Vec<Burst>) {
        if buf.capacity() > self.out.capacity() {
            buf.clear();
            self.out = std::mem::take(buf);
        }
    }

    /// Hand the burst buffer back for the next run on this worker.
    pub fn reclaim_buffer(&mut self, buf: &mut Vec<Burst>) {
        *buf = std::mem::take(&mut self.out);
        buf.clear();
    }

    /// Execute one lifecycle phase.
    pub fn push_phase(&mut self, phase: Phase, graph: &CsrGraph) {
        match phase {
            Phase::Forward { layer } => {
                assert!(
                    layer < self.cfg.layers,
                    "phase layer {layer} out of range (cfg.layers = {})",
                    self.cfg.layers
                );
                self.mark_span(SpanKind::Forward { layer });
                // Attribution boundary only — no drain, so the DRAM
                // traffic (and the golden-parity metrics) are untouched;
                // at most a scheduling window of in-flight bursts bleeds
                // into the next bucket.
                self.credit_reads();
                self.crediting_backward = false;
                if layer != self.current_layer {
                    self.advance_layer(layer);
                }
                // Compute is charged per drive for the graph actually
                // driven: layer 1 consumes (flen → hidden), deeper layers
                // (hidden → hidden). Sampled epochs therefore cost their
                // subgraph. For the single-epoch full-batch schedules the
                // golden-parity suite pins, this accumulation is bit-exact
                // with the legacy `per_epoch × (3 if backward)` form;
                // multi-epoch sums may differ from the old `n × cost`
                // product by float rounding (ulps).
                self.compute_ns += self.layer_cost(layer, graph);
                if layer == 0 {
                    self.sampled_edges += graph.num_edges() as u64;
                }
                self.drive_edges(graph.edge_iter());
            }
            Phase::Backward => {
                self.mark_span(SpanKind::Backward);
                self.credit_reads();
                self.crediting_backward = true;
                // A backward drive is a full-gradient pass over every
                // configured layer, ≈ 2× one forward epoch (input +
                // weight gradients) over the epoch's (sub)graph.
                self.compute_ns += 2.0 * self.full_pass_cost(graph);
                // The transpose is a pure function of the graph — cached
                // on the instance, so sweeps sharing a graph pay the O(E)
                // rebuild exactly once.
                self.drive_edges(graph.transposed().edge_iter());
            }
            Phase::WriteBack => {
                self.push_write_back(graph.num_vertices() as u32);
            }
            Phase::MaskWriteBack => {
                self.push_mask_write_back();
            }
        }
    }

    /// Aggregation write-back for an explicit vertex count — the entry
    /// point of the frontier-limited and sharded schedules, which write
    /// back only the vertices a phase actually produced (the sampled
    /// frontier, or one shard's row range) instead of the full vertex
    /// set. `push_phase(Phase::WriteBack, g)` is exactly
    /// `push_write_back(g.num_vertices())`.
    pub fn push_write_back(&mut self, vertices: u32) {
        self.mark_span(SpanKind::WriteBack);
        self.write_back(vertices);
    }

    /// Dropout-mask write-back as a standalone step (covers the feature
    /// instances processed since the previous mask write-back —
    /// identical to `push_phase(Phase::MaskWriteBack, _)`, which needs
    /// no graph).
    pub fn push_mask_write_back(&mut self) {
        self.mark_span(SpanKind::MaskWriteBack);
        self.write_masks();
    }

    /// Record that the sharded schedule switched the resident shard: a
    /// zero-width `shard_load` marker span with an empty delta, so
    /// sharded traces still telescope to run totals (same contract as
    /// [`note_preempt`](Self::note_preempt)).
    pub fn note_shard_load(&mut self, shard: usize) {
        let Some(rec) = self.rec.as_deref_mut() else { return };
        let cycle = self.dram.busy_until();
        rec.record_span(SpanEvent {
            kind: SpanKind::ShardLoad { shard },
            epoch: self.epoch,
            tenant: self.span_tenant,
            start_cycle: cycle,
            end_cycle: cycle,
            dram: DramDelta::default(),
        });
    }

    /// Sync point: drain LiGNN residue, in-flight interleaved reads and
    /// the memory-controller window. Call before write-back phases and at
    /// layer/epoch boundaries.
    pub fn drain(&mut self) {
        self.unit.flush(&mut self.out);
        if let Some(il) = &mut self.interleaver {
            il.flush(&mut self.out);
        }
        self.issue();
        self.drain_sched();
        self.credit_reads();
    }

    /// Compute-side cost of one forward drive of `layer` over `graph`
    /// (layer 0 consumes the raw features, deeper layers the hidden
    /// intermediates).
    fn layer_cost(&self, layer: usize, graph: &CsrGraph) -> f64 {
        let cfg = self.cfg;
        if layer == 0 {
            self.engine.compute_ns(cfg.model, graph, cfg.flen, cfg.hidden)
        } else {
            self.engine.compute_ns(cfg.model, graph, cfg.hidden, cfg.hidden)
        }
    }

    /// Cost of one full forward pass (all configured layers) over `graph`.
    fn full_pass_cost(&self, graph: &CsrGraph) -> f64 {
        let mut per_epoch = self.layer_cost(0, graph);
        for l in 1..self.cfg.layers {
            per_epoch += self.layer_cost(l, graph);
        }
        per_epoch
    }

    /// Close the run: final drain, trace flush, session accounting, and
    /// metric assembly. The engine is spent afterwards.
    pub fn finish(&mut self, _graph: &CsrGraph) -> Metrics {
        // No-op when the canonical schedule already drained; salvages
        // stragglers otherwise.
        self.drain();
        self.close_span();
        if let Some(t) = self.trace.take() {
            t.finish().expect("flushing trace");
        }
        self.dram.flush_sessions();

        // Classify feature instances (hit counted at cache probe).
        let (mut feat_new, mut feat_merge, mut feat_dropped) = (0u64, 0u64, 0u64);
        for s in &self.served {
            match s {
                Served::Opened => feat_new += 1,
                Served::Merged => feat_merge += 1,
                Served::None => feat_dropped += 1,
            }
        }
        let mut unit_stats = self.retired.clone();
        merge_stats(&mut unit_stats, &self.unit.stats);
        // Instances whose bursts were all dropped before any DRAM issue
        // never made it into `served`.
        feat_dropped += unit_stats.features_in - self.served.len() as u64;

        // Compute was accumulated per drive as phases executed (each
        // drive charged for the graph it actually drove).
        let compute_ns = self.compute_ns;
        let mem_ns = self.dram.busy_ns();

        let energy = EnergyReport::from_counters(self.dram.config(), &self.dram.counters);
        Metrics {
            variant: self.cfg.variant.name().to_string(),
            graph: self.cfg.graph.name().to_string(),
            model: self.cfg.model.name().to_string(),
            dram_standard: self.cfg.dram.name().to_string(),
            alpha: self.cfg.alpha,
            exec_ns: mem_ns.max(compute_ns),
            mem_ns,
            compute_ns,
            unit: unit_stats,
            dram: self.dram.counters.clone(),
            energy,
            cache_hits: self.cache.hits(),
            cache_misses: self.cache.misses(),
            feat_hit: self.feat_hit,
            feat_new,
            feat_merge,
            feat_dropped,
            layer_reads: self.layer_reads.clone(),
            backward_reads: self.backward_reads,
            sampler: std::mem::take(&mut self.sampler_label),
            sampled_edges: self.sampled_edges,
        }
    }

    /// The shared edge-drive routine: one loop body for the merged
    /// (LG-T/LM) and plain paths, for any phase's edge stream.
    fn drive_edges(&mut self, edges: impl Iterator<Item = (u32, u32)>) {
        if self.cfg.variant.uses_merge() {
            // Edges pass through the REC merger first (§4.2). The REC CAM
            // is sized to the scheduling range (a class per pending edge
            // in the worst case, capped at 1024 — still a small edge
            // table, §5.2.4 prices it at ~0.01 mm²).
            let calc = *self.unit.calc();
            let mut merger = RecMerger::new(calc, self.cfg.range, self.cfg.range.min(1024));
            for (dst, src) in edges {
                for group in merger.push(Edge { dst, src }) {
                    self.drive_group(group);
                }
            }
            for group in merger.flush() {
                self.drive_group(group);
            }
        } else {
            for (_dst, src) in edges {
                self.process(src, false);
            }
        }
    }

    /// Multi-edge REC groups (same DRAM row class) issue clustered — one
    /// access sequence from the merger hardware; the singleton remainder
    /// flows through the engine's normal read path.
    fn drive_group(&mut self, group: Vec<Edge>) {
        let clustered = group.len() > 1;
        for e in group {
            self.process(e.src, clustered);
        }
    }

    /// Process one aggregation edge: cache probe, then LiGNN, then issue
    /// whatever the unit emitted to DRAM (through the MLP interleaver for
    /// the non-LGT paths). `clustered` bypasses the interleaver — used
    /// for multi-edge REC groups (§4.2).
    fn process(&mut self, src: u32, clustered: bool) {
        if self.cache.access(src) {
            self.feat_hit += 1;
            return;
        }
        match &mut self.interleaver {
            Some(_) if !clustered => {
                let mut feature =
                    Vec::with_capacity(self.unit.calc().bursts_per_feature() as usize);
                self.unit.push_feature(src, &mut feature);
                let il = self.interleaver.as_mut().expect("interleaver present");
                il.push(feature, &mut self.out);
            }
            _ => {
                self.unit.push_feature(src, &mut self.out);
            }
        }
        self.issue();
    }

    /// Issue buffered bursts toward DRAM (through the memory controller's
    /// FR-FCFS window) in the unit's locality order.
    fn issue(&mut self) {
        let served = &mut self.served;
        let base = self.seq_base;
        let mut sink = |seq: u32, activated: bool| mark(served, base, seq, activated);
        for b in self.out.drain(..) {
            if let Some(t) = &mut self.trace {
                t.read(b.addr).expect("trace write");
            }
            self.sched.push(b, &mut self.dram, &mut sink);
        }
    }

    fn drain_sched(&mut self) {
        let served = &mut self.served;
        let base = self.seq_base;
        let mut sink = |seq: u32, activated: bool| mark(served, base, seq, activated);
        self.sched.flush(&mut self.dram, &mut sink);
    }

    /// Credit DRAM reads since the last mark to the live bucket (the
    /// current forward layer, or the backward accumulator).
    fn credit_reads(&mut self) {
        let now = self.dram.counters.reads;
        let delta = now - self.reads_mark;
        self.reads_mark = now;
        if self.crediting_backward {
            self.backward_reads += delta;
        } else {
            self.layer_reads[self.current_layer] += delta;
        }
    }

    /// Layer boundary: a global sync (aggregation of layer l+1 consumes
    /// layer l's combination output), then swap in a unit addressing the
    /// intermediate region. Counters persist; cache contents are stale
    /// across the boundary (a different value space) and are cleared.
    fn advance_layer(&mut self, layer: usize) {
        self.drain();
        self.seq_base += self.unit.stats.features_in as usize;
        merge_stats(&mut self.retired, &self.unit.stats);
        self.unit_swaps += 1;
        let seed = self
            .cfg
            .seed
            .wrapping_add(LAYER_SEED_STRIDE.wrapping_mul(self.unit_swaps));
        self.unit = self.make_unit(layer, seed);
        self.cache.clear();
        self.current_layer = layer;
    }

    fn make_unit(&self, layer: usize, seed: u64) -> LignnUnit {
        Self::build_unit(self.cfg, &self.dram, layer, seed)
    }

    /// The one construction site for per-layer units (layer 0 at the raw
    /// feature base, layer `l ≥ 1` at the intermediate buffer layer
    /// `l − 1` wrote).
    fn build_unit(cfg: &SimConfig, dram: &DramModel, layer: usize, seed: u64) -> LignnUnit {
        let (base, flen_bytes) = if layer == 0 {
            (cfg.feat_base, cfg.flen_bytes())
        } else {
            (intermediate_base(cfg, dram, layer - 1), (cfg.hidden * 4) as u64)
        };
        let calc = AddressCalc::new(*dram.mapping(), base, flen_bytes);
        let criteria = if cfg.channel_balance {
            Criteria::ChannelBalance
        } else {
            Criteria::Any
        };
        LignnUnit::new(cfg.variant, calc, cfg.alpha, cfg.range, criteria, seed)
    }

    /// Aggregation write-back: one output feature per vertex, streamed
    /// sequentially into a disjoint region. Single-layer runs keep the
    /// legacy `flen`-wide output; multi-layer runs write `hidden`-wide
    /// intermediates (what the next layer reads back). Layer `l` writes
    /// intermediate buffer `l % 2` — the one the *next* layer reads, and
    /// never the one this layer's own aggregation is reading from.
    fn write_back(&mut self, n: u32) {
        let out_bytes = if self.cfg.layers == 1 {
            self.cfg.flen_bytes()
        } else {
            (self.cfg.hidden * 4) as u64
        };
        // Each intermediate buffer spans a quarter of the address space
        // minus the feature base (buffer 1 starts at feat_base + 3·cap/4,
        // so its last feat_base bytes would decode-wrap past capacity); a
        // spill would silently alias the other buffer or the feature
        // region, so fail loudly instead. Single-layer runs keep the
        // legacy unchecked layout — they never touch buffer 1.
        if self.cfg.layers > 1 {
            let quarter = self.dram.mapping().capacity_bytes() >> 2;
            let headroom = quarter.saturating_sub(self.cfg.feat_base);
            assert!(
                n as u64 * out_bytes <= headroom,
                "intermediate write-back ({n} vertices × {out_bytes} B) exceeds the \
                 {headroom}-byte double-buffer region of {}",
                self.cfg.dram.name()
            );
        }
        let out_base = intermediate_base(self.cfg, &self.dram, self.current_layer);
        let mapping = *self.dram.mapping();
        for v in 0..n as u64 {
            let addr = out_base + v * out_bytes;
            // Sequential write-back is exactly the traffic the run-
            // coalesced path exists for: whole row-group runs at a time.
            for run in mapping.runs_for_range(addr, out_bytes) {
                if let Some(t) = &mut self.trace {
                    for (a, _) in mapping.run_bursts(run) {
                        t.write(a).expect("trace write");
                    }
                }
                self.dram.write_run(run.start, run.bursts, 0);
            }
        }
    }

    /// §4.3: the dropout mask (1 bit per feature element, stored
    /// continuously like an edge feature) is written back for the backward
    /// pass. Sequential single-bit-per-element traffic — "good locality,
    /// in contrast to reading the feature data". Covers the feature
    /// instances processed since the previous mask write-back.
    fn write_masks(&mut self) {
        if !self.cfg.mask_writeback || self.cfg.alpha == 0.0 {
            return;
        }
        let total_in = self.retired.features_in + self.unit.stats.features_in;
        let fresh = total_in - self.mask_mark;
        self.mask_mark = total_in;
        let elems = if self.current_layer == 0 { self.cfg.flen } else { self.cfg.hidden };
        let mask_bytes = fresh * (elems as u64).div_ceil(8);
        let mask_base = self.cfg.feat_base + (self.dram.mapping().capacity_bytes() >> 2);
        let mapping = *self.dram.mapping();
        for run in mapping.runs_for_range(mask_base, mask_bytes) {
            if let Some(t) = &mut self.trace {
                for (a, _) in mapping.run_bursts(run) {
                    t.write(a).expect("trace write");
                }
            }
            self.dram.write_run(run.start, run.bursts, 0);
        }
    }
}

/// One schedule boundary: hand the hook the cursor plus the request
/// chunk accumulated since the previous boundary; a `true` return means
/// the hook actually parked the engine (ran other work nested), so a
/// `preempt` marker is recorded.
fn boundary(
    engine: &mut SimEngine<'_>,
    hook: &mut PhaseHook<'_>,
    epoch: usize,
    layer: usize,
    next: NextStep,
) {
    let chunk = engine.take_request_log();
    if hook(PhaseCursor { epoch: epoch as u32, layer, next }, chunk) {
        engine.note_preempt();
    }
}

/// Vertices the aggregation write-back covers for one epoch's subgraph:
/// the full vertex set by default (the legacy layout every golden run
/// pins), or only the sampled frontier — vertices the epoch actually
/// aggregated into — under `cfg.frontier_writeback`, so write-back
/// traffic scales with the mini-batch instead of the graph. Full-batch
/// epochs on a graph with no isolated vertices write the same count
/// either way.
fn write_back_count(cfg: &SimConfig, sub: &EpochSubgraph<'_>) -> u32 {
    if cfg.frontier_writeback {
        sub.seeds().len() as u32
    } else {
        sub.graph().num_vertices() as u32
    }
}

/// Drive `engine` through the canonical schedule its config implies:
/// `epochs × (sample + layers forward + [backward after the last layer]
/// + write-backs)`, consulting `hook` at every phase boundary.
fn run_schedule(engine: &mut SimEngine<'_>, graph: &CsrGraph, hook: &mut PhaseHook<'_>) -> Metrics {
    if engine.cfg.layerwise_sampling() {
        return run_layerwise_schedule(engine, graph, hook);
    }
    let sampler = engine.cfg.build_sampler();
    run_schedule_with(engine, graph, sampler.as_ref(), hook)
}

/// Layer-wise fanouts (`--fanout 10,5`): every layer samples its *own*
/// subgraph at its hop budget, re-sampled each epoch; the backward
/// phase follows the last hop's subset (the gradient stream of the
/// deepest aggregation). The single-value form never reaches this path
/// — it keeps the one-subgraph-per-epoch schedule bit-for-bit.
fn run_layerwise_schedule(
    engine: &mut SimEngine<'_>,
    graph: &CsrGraph,
    hook: &mut PhaseHook<'_>,
) -> Metrics {
    let cfg = engine.cfg;
    let samplers: Vec<Box<dyn Sampler>> =
        (0..cfg.layers).map(|l| cfg.build_sampler_for_layer(l)).collect();
    for epoch in 0..cfg.epochs {
        engine.set_epoch(epoch as u32);
        for (layer, sampler) in samplers.iter().enumerate() {
            boundary(engine, hook, epoch, layer, NextStep::Sample);
            engine.note_sample();
            let sub = sampler.sample(graph, epoch as u64);
            let g = sub.graph();
            boundary(engine, hook, epoch, layer, NextStep::Forward);
            engine.push_phase(Phase::Forward { layer }, g);
            if layer + 1 == cfg.layers && cfg.backward {
                boundary(engine, hook, epoch, layer, NextStep::Backward);
                engine.push_phase(Phase::Backward, g);
            }
            engine.drain();
            boundary(engine, hook, epoch, layer, NextStep::WriteBack);
            engine.push_write_back(write_back_count(cfg, &sub));
            boundary(engine, hook, epoch, layer, NextStep::MaskWriteBack);
            engine.push_mask_write_back();
        }
    }
    engine.finish(graph)
}

/// The subgraph-aware schedule: every epoch re-samples, and the whole
/// epoch — forward drives, the dropout mask they generate, the backward
/// transpose — follows the sampled subset. Full-batch sampling yields
/// the original graph instance, so it is bit-identical to driving
/// `graph` directly.
fn run_schedule_with(
    engine: &mut SimEngine<'_>,
    graph: &CsrGraph,
    sampler: &dyn Sampler,
    hook: &mut PhaseHook<'_>,
) -> Metrics {
    let cfg = engine.cfg;
    for epoch in 0..cfg.epochs {
        engine.set_epoch(epoch as u32);
        boundary(engine, hook, epoch, 0, NextStep::Sample);
        engine.note_sample();
        let sub = sampler.sample(graph, epoch as u64);
        let g = sub.graph();
        for layer in 0..cfg.layers {
            boundary(engine, hook, epoch, layer, NextStep::Forward);
            engine.push_phase(Phase::Forward { layer }, g);
            if layer + 1 == cfg.layers && cfg.backward {
                boundary(engine, hook, epoch, layer, NextStep::Backward);
                engine.push_phase(Phase::Backward, g);
            }
            engine.drain();
            boundary(engine, hook, epoch, layer, NextStep::WriteBack);
            engine.push_write_back(write_back_count(cfg, &sub));
            boundary(engine, hook, epoch, layer, NextStep::MaskWriteBack);
            engine.push_mask_write_back();
        }
    }
    engine.finish(graph)
}

/// Run one full simulation; deterministic in `cfg.seed`. Thin
/// compatibility wrapper over [`SimEngine`] — identical metrics to the
/// pre-engine driver for single-layer, single-epoch, full-batch configs.
pub fn run_sim(cfg: &SimConfig, graph: &CsrGraph) -> Metrics {
    let mut engine = SimEngine::new(cfg);
    run_schedule(&mut engine, graph, &mut |_, _| false)
}

/// [`run_sim`] with an explicit sampling policy overriding
/// `cfg.sampler` — the hook for policies outside
/// [`SamplerKind`](crate::sample::SamplerKind).
pub fn run_sampled_sim(cfg: &SimConfig, graph: &CsrGraph, sampler: &dyn Sampler) -> Metrics {
    let mut engine = SimEngine::new(cfg);
    engine.set_sampler_label(sampler.name());
    run_schedule_with(&mut engine, graph, sampler, &mut |_, _| false)
}

/// [`run_sim`] with a caller-owned burst buffer recycled across runs —
/// the per-worker entry point of the shared
/// [`EnginePool`](crate::serve::EnginePool) scheduler: both sweep
/// points and serve jobs reach the engine through this function, one
/// recycled buffer per pool worker.
pub fn run_sim_with_buffer(cfg: &SimConfig, graph: &CsrGraph, buf: &mut Vec<Burst>) -> Metrics {
    let mut engine = SimEngine::new(cfg);
    engine.recycle_buffer(buf);
    let m = run_schedule(&mut engine, graph, &mut |_, _| false);
    engine.reclaim_buffer(buf);
    m
}

/// [`run_sim`] with a telemetry [`Recorder`] attached: identical
/// schedule, identical metrics (golden parity pins recorded runs
/// bit-identical to bare ones), plus per-phase span events delivered to
/// `rec` at each boundary. Pass a
/// [`TraceRecorder`](crate::telemetry::TraceRecorder) for export or a
/// [`PhaseActs`](crate::telemetry::PhaseActs) for attribution only.
pub fn run_sim_recorded(cfg: &SimConfig, graph: &CsrGraph, rec: &mut dyn Recorder) -> Metrics {
    let mut engine = SimEngine::new(cfg);
    engine.set_recorder(rec);
    run_schedule(&mut engine, graph, &mut |_, _| false)
}

/// [`run_sim`] with a [`SpatialProfiler`] attached (top-`topk` hot-row
/// sketch): identical schedule, identical metrics — the profiler only
/// observes the DRAM command stream (golden parity pins profiled runs
/// bit-identical to bare ones). Returns the run metrics together with
/// the filled profiler, whose grids/sketch telescope exactly to the
/// metrics' `DramCounters` (see `tests/properties.rs`).
pub fn run_sim_profiled(
    cfg: &SimConfig,
    graph: &CsrGraph,
    topk: usize,
) -> (Metrics, Box<SpatialProfiler>) {
    let mut engine = SimEngine::new(cfg);
    engine.enable_profiler(topk);
    let m = run_schedule(&mut engine, graph, &mut |_, _| false);
    let p = engine.take_profiler().expect("profiler was enabled above");
    (m, p)
}

/// [`run_sim_profiled`] with a telemetry [`Recorder`] attached too —
/// the CLI's `simulate --heatmap --trace/--prom` path, where the trace
/// and Prometheus exports carry the profiler's per-bank series beside
/// the phase spans.
pub fn run_sim_recorded_profiled(
    cfg: &SimConfig,
    graph: &CsrGraph,
    rec: &mut dyn Recorder,
    topk: usize,
) -> (Metrics, Box<SpatialProfiler>) {
    let mut engine = SimEngine::new(cfg);
    engine.set_recorder(rec);
    engine.enable_profiler(topk);
    let m = run_schedule(&mut engine, graph, &mut |_, _| false);
    let p = engine.take_profiler().expect("profiler was enabled above");
    (m, p)
}

/// [`run_sim_recorded`] with a caller-owned recycled burst buffer — the
/// QoS workers' entry point (per-job phase attribution on a long-lived
/// worker's buffer).
pub fn run_sim_recorded_with_buffer(
    cfg: &SimConfig,
    graph: &CsrGraph,
    buf: &mut Vec<Burst>,
    rec: &mut dyn Recorder,
) -> Metrics {
    let mut engine = SimEngine::new(cfg);
    engine.recycle_buffer(buf);
    engine.set_recorder(rec);
    let m = run_schedule(&mut engine, graph, &mut |_, _| false);
    engine.reclaim_buffer(buf);
    m
}

/// The QoS workers' preemptible entry point: the canonical schedule
/// with `hook` consulted at every phase boundary. `tenant` stamps every
/// recorded span; `log_requests` turns on DRAM request capture so each
/// boundary's chunk reaches the hook (shared-device replay). A trailing
/// `NextStep::Finish` boundary fires after `finish` with the final
/// chunk (its preempt return is ignored — nothing is left to park).
///
/// Preemption model: the hook runs *nested* on this thread while the
/// engine sits untouched on the stack, so resuming is simply
/// returning. `tests` pin that a run preempted at every boundary in
/// turn produces bit-identical metrics to the uninterrupted run.
pub fn run_sim_preemptible_with_buffer(
    cfg: &SimConfig,
    graph: &CsrGraph,
    buf: &mut Vec<Burst>,
    rec: &mut dyn Recorder,
    tenant: u32,
    log_requests: bool,
    hook: &mut PhaseHook<'_>,
) -> Metrics {
    let mut engine = SimEngine::new(cfg);
    engine.recycle_buffer(buf);
    engine.set_recorder(rec);
    engine.set_span_tenant(tenant);
    if log_requests {
        engine.enable_request_log();
    }
    let m = run_schedule(&mut engine, graph, hook);
    let tail = engine.take_request_log();
    let cursor =
        PhaseCursor { epoch: cfg.epochs as u32, layer: 0, next: NextStep::Finish };
    let _ = hook(cursor, tail);
    engine.reclaim_buffer(buf);
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GraphPreset, SamplerKind, Variant};

    fn cfg(variant: Variant, alpha: f64) -> SimConfig {
        SimConfig {
            graph: GraphPreset::Tiny,
            variant,
            alpha,
            flen: 64,
            capacity: 256,
            access: 64,
            range: 64,
            ..Default::default()
        }
    }

    fn run(variant: Variant, alpha: f64) -> Metrics {
        let c = cfg(variant, alpha);
        let g = c.build_graph();
        run_sim(&c, &g)
    }

    #[test]
    fn baseline_alpha_zero_reads_all_misses() {
        let m = run(Variant::A, 0.0);
        // every cache miss expands to flen*4/32 bursts, all kept
        let bpf = 64 * 4 / 32;
        assert_eq!(m.dram.reads, m.cache_misses * bpf);
        assert_eq!(m.feat_dropped, 0);
        assert_eq!(m.unit.desired_elems, m.unit.total_elems);
    }

    #[test]
    fn variants_preserve_workload_identity() {
        // Same graph, same cache → same number of feature requests for
        // non-merge variants.
        let a = run(Variant::A, 0.5);
        let b = run(Variant::B, 0.5);
        let s = run(Variant::S, 0.5);
        assert_eq!(a.unit.features_in, b.unit.features_in);
        assert_eq!(a.unit.features_in, s.unit.features_in);
        assert_eq!(a.cache_hits + a.cache_misses, s.cache_hits + s.cache_misses);
    }

    /// Non-degenerate config: flen=256 (4 bursts per channel per feature)
    /// over the Small graph, so row-level locality has room to act.
    fn cfg_meaningful(variant: Variant, alpha: f64) -> SimConfig {
        SimConfig {
            graph: GraphPreset::Small,
            variant,
            alpha,
            flen: 256,
            capacity: 1024,
            access: 256,
            range: 256,
            ..Default::default()
        }
    }

    fn run_meaningful(variant: Variant, alpha: f64) -> Metrics {
        let c = cfg_meaningful(variant, alpha);
        let g = c.build_graph();
        run_sim(&c, &g)
    }

    #[test]
    fn lgt_variant_reduces_activations_vs_baseline() {
        let a = run_meaningful(Variant::A, 0.5);
        let s = run_meaningful(Variant::S, 0.5);
        assert!(
            s.dram.activations < a.dram.activations,
            "LG-S acts {} !< LG-A acts {}",
            s.dram.activations,
            a.dram.activations
        );
        assert!(s.dram.reads < a.dram.reads);
    }

    #[test]
    fn merge_at_least_matches_lgt_alone() {
        // On top of the LGT's grouping the REC merger adds little at this
        // scale (the LGT already captures most same-row coalescing within
        // its scheduling range); assert parity within noise. The isolated
        // merge effect is asserted by `merge_only_beats_interleaved_baseline`.
        let s = run_meaningful(Variant::S, 0.5);
        let t = run_meaningful(Variant::T, 0.5);
        let ratio = t.dram.activations as f64 / s.dram.activations as f64;
        assert!(
            ratio < 1.05,
            "LG-T acts {} vs LG-S acts {}",
            t.dram.activations,
            s.dram.activations
        );
    }

    #[test]
    fn merge_only_beats_interleaved_baseline() {
        // §5.4's LM vs NM: the merge-only variant at α=0 against the plain
        // interleaved engine at α=0 — merging alone must cut activations
        // and time (paper: 1.3–1.6× speedup).
        let nm = run_meaningful(Variant::A, 0.0);
        let lm = run_meaningful(Variant::M, 0.0);
        assert!(
            (lm.dram.activations as f64) < 0.9 * nm.dram.activations as f64,
            "LM acts {} !< NM acts {}",
            lm.dram.activations,
            nm.dram.activations
        );
        assert!(lm.exec_ns < nm.exec_ns);
        // merging never drops anything
        assert_eq!(lm.unit.bursts_kept, lm.unit.bursts_in);
    }

    #[test]
    fn exec_time_monotone_in_alpha_for_row_variants() {
        let lo = run(Variant::S, 0.1);
        let hi = run(Variant::S, 0.8);
        assert!(hi.exec_ns < lo.exec_ns);
    }

    #[test]
    fn breakdown_partitions_features() {
        let m = run(Variant::T, 0.3);
        assert_eq!(
            m.feat_new + m.feat_merge + m.feat_dropped,
            m.unit.features_in,
            "breakdown must partition DRAM-bound features"
        );
        assert_eq!(m.feat_hit, m.cache_hits);
    }

    #[test]
    fn backward_pass_adds_traffic_keeps_ratios() {
        let fwd = cfg_meaningful(Variant::T, 0.5);
        let mut both = cfg_meaningful(Variant::T, 0.5);
        both.backward = true;
        let g = fwd.build_graph();
        let f = run_sim(&fwd, &g);
        let b = run_sim(&both, &g);
        assert!(b.dram.reads > f.dram.reads, "backward must add reads");
        assert!(b.exec_ns > f.exec_ns);
        assert!(b.backward_reads > 0, "gradient reads must be attributed");
        assert_eq!(f.backward_reads, 0);
        // and the variant still drops at the configured rate overall
        let kept = b.unit.bursts_kept as f64 / b.unit.bursts_in as f64;
        assert!((kept - 0.5).abs() < 0.08, "kept {kept}");
    }

    #[test]
    fn trace_capture_replays_identically() {
        let dir = std::env::temp_dir().join("lignn-driver-trace");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.trace");
        let mut c = cfg(Variant::S, 0.5);
        c.trace_path = Some(path.to_string_lossy().into_owned());
        let g = c.build_graph();
        let live = run_sim(&c, &g);
        let (counters, _) = crate::sim::trace::replay(
            &path,
            crate::dram::DramModel::new(c.dram.config()),
        )
        .unwrap();
        // Replay through a fresh device (no FR-FCFS window) preserves the
        // transaction counts; activations match because the trace records
        // post-scheduling issue order.
        assert_eq!(counters.reads, live.dram.reads);
        assert_eq!(counters.writes, live.dram.writes);
    }

    #[test]
    fn deterministic_across_runs() {
        let x = run(Variant::T, 0.5);
        let y = run(Variant::T, 0.5);
        assert_eq!(x.dram.reads, y.dram.reads);
        assert_eq!(x.dram.activations, y.dram.activations);
        assert_eq!(x.exec_ns, y.exec_ns);
    }

    #[test]
    fn writes_present_for_all_variants() {
        for v in [Variant::A, Variant::B, Variant::R, Variant::S, Variant::T] {
            let m = run(v, 0.5);
            let g = cfg(v, 0.5).build_graph();
            let bpf = 64 * 4 / 32;
            let agg_writes = g.num_vertices() as u64 * bpf;
            // aggregation write-back plus the §4.3 mask write-back
            let mask_writes = (m.unit.features_in * (64u64).div_ceil(8)).div_ceil(32);
            assert_eq!(m.dram.writes, agg_writes + mask_writes, "{v:?}");
        }
    }

    #[test]
    fn mask_writeback_toggle() {
        let mut with = cfg(Variant::S, 0.5);
        with.mask_writeback = true;
        let mut without = cfg(Variant::S, 0.5);
        without.mask_writeback = false;
        let g = with.build_graph();
        let a = run_sim(&with, &g);
        let b = run_sim(&without, &g);
        assert!(a.dram.writes > b.dram.writes);
        assert_eq!(a.dram.reads, b.dram.reads);
    }

    #[test]
    fn channel_balance_criteria_runs() {
        let mut c = cfg(Variant::S, 0.5);
        c.channel_balance = true;
        let g = c.build_graph();
        let m = run_sim(&c, &g);
        assert!(m.exec_ns > 0.0);
        assert_eq!(
            m.unit.bursts_in,
            m.unit.bursts_kept + m.unit.bursts_filter_dropped + m.unit.bursts_row_dropped
        );
    }

    // ------------------------------------------------------------------
    // SimEngine lifecycle
    // ------------------------------------------------------------------

    #[test]
    fn explicit_phase_composition_matches_wrapper() {
        // Hand-composing the canonical schedule through the public phase
        // API must equal run_sim exactly — the wrapper adds nothing.
        for variant in [Variant::A, Variant::T] {
            let mut c = cfg(variant, 0.5);
            c.backward = true;
            let g = c.build_graph();
            let via_wrapper = run_sim(&c, &g);

            let mut e = SimEngine::new(&c);
            e.push_phase(Phase::Forward { layer: 0 }, &g);
            e.push_phase(Phase::Backward, &g);
            e.drain();
            e.push_phase(Phase::WriteBack, &g);
            e.push_phase(Phase::MaskWriteBack, &g);
            let via_engine = e.finish(&g);

            assert_eq!(via_wrapper.dram.reads, via_engine.dram.reads, "{variant:?}");
            assert_eq!(via_wrapper.dram.writes, via_engine.dram.writes);
            assert_eq!(via_wrapper.dram.activations, via_engine.dram.activations);
            assert_eq!(via_wrapper.exec_ns, via_engine.exec_ns);
            assert_eq!(via_wrapper.feat_new, via_engine.feat_new);
            assert_eq!(via_wrapper.feat_merge, via_engine.feat_merge);
            assert_eq!(via_wrapper.feat_dropped, via_engine.feat_dropped);
        }
    }

    #[test]
    fn buffer_recycling_is_metrics_neutral() {
        let c = cfg(Variant::T, 0.5);
        let g = c.build_graph();
        let plain = run_sim(&c, &g);
        let mut buf = Vec::with_capacity(1 << 14);
        let a = run_sim_with_buffer(&c, &g, &mut buf);
        let cap_after_first = buf.capacity();
        let b = run_sim_with_buffer(&c, &g, &mut buf);
        assert!(buf.capacity() >= cap_after_first, "capacity must survive");
        for m in [&a, &b] {
            assert_eq!(m.dram.reads, plain.dram.reads);
            assert_eq!(m.dram.activations, plain.dram.activations);
            assert_eq!(m.exec_ns, plain.exec_ns);
        }
    }

    #[test]
    fn two_layers_run_and_layer1_dominates() {
        let mut c = cfg_meaningful(Variant::T, 0.5);
        c.layers = 2;
        let g = c.build_graph();
        let m = run_sim(&c, &g);
        assert_eq!(m.layer_reads.len(), 2);
        assert!(m.layer_reads[0] > 0 && m.layer_reads[1] > 0);
        // flen=256 raw features vs hidden=64 intermediates: the first
        // aggregation must dominate DRAM reads — the paper's premise,
        // measured.
        assert!(
            m.layer_reads[0] > 2 * m.layer_reads[1],
            "layer 1 reads {} do not dominate layer 2 reads {}",
            m.layer_reads[0],
            m.layer_reads[1]
        );
        assert_eq!(
            m.layer_reads.iter().sum::<u64>() + m.backward_reads,
            m.dram.reads
        );
        assert_eq!(m.backward_reads, 0, "no backward phase in this run");
        // the classification still partitions all feature instances
        assert_eq!(
            m.feat_new + m.feat_merge + m.feat_dropped,
            m.unit.features_in,
        );
        assert_eq!(m.feat_hit, m.cache_hits);
    }

    #[test]
    fn second_layer_adds_traffic_over_single() {
        let one = cfg_meaningful(Variant::S, 0.5);
        let mut two = one.clone();
        two.layers = 2;
        let g = one.build_graph();
        let m1 = run_sim(&one, &g);
        let m2 = run_sim(&two, &g);
        assert!(m2.dram.reads > m1.dram.reads);
        assert!(m2.unit.features_in > m1.unit.features_in);
    }

    #[test]
    fn epochs_scale_traffic_and_compute() {
        let e1 = cfg(Variant::S, 0.5);
        let mut e2 = e1.clone();
        e2.epochs = 2;
        let g = e1.build_graph();
        let m1 = run_sim(&e1, &g);
        let m2 = run_sim(&e2, &g);
        assert!(m2.dram.writes > m1.dram.writes, "two write-backs expected");
        assert!(m2.dram.reads > m1.dram.reads);
        assert!((m2.compute_ns / m1.compute_ns - 2.0).abs() < 1e-9);
    }

    // ------------------------------------------------------------------
    // Double-buffered intermediate region
    // ------------------------------------------------------------------

    #[test]
    fn intermediate_buffers_alternate_and_stay_aligned() {
        let c = cfg(Variant::S, 0.5);
        let dram = DramModel::new(c.dram.config());
        let b0 = intermediate_base(&c, &dram, 0);
        let b1 = intermediate_base(&c, &dram, 1);
        assert_ne!(b0, b1);
        let group = dram.mapping().row_group_bytes();
        assert_eq!(b0 % group, 0, "buffer 0 must stay row-group aligned");
        assert_eq!(b1 % group, 0, "buffer 1 must stay row-group aligned");
        assert_eq!(intermediate_base(&c, &dram, 2), b0, "buffers alternate");
        assert_eq!(intermediate_base(&c, &dram, 3), b1);
    }

    #[test]
    fn double_buffer_prevents_intermediate_read_write_aliasing() {
        // Two layers, traced: layer 1 writes buffer 0; layer 2 reads
        // buffer 0 and writes buffer 1 — so no read ever lands in the
        // buffer its own layer is writing.
        let dir = std::env::temp_dir().join("lignn-driver-dbuf");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("dbuf.trace");
        let mut c = cfg_meaningful(Variant::S, 0.5);
        c.layers = 2;
        c.trace_path = Some(path.to_string_lossy().into_owned());
        let g = c.build_graph();
        let _ = run_sim(&c, &g);
        let dram = DramModel::new(c.dram.config());
        let b0 = intermediate_base(&c, &dram, 0);
        let b1 = intermediate_base(&c, &dram, 1);
        let content = std::fs::read_to_string(&path).unwrap();
        let (mut reads_b0, mut reads_b1, mut writes_b0, mut writes_b1) = (0u64, 0u64, 0u64, 0u64);
        for line in content.lines() {
            let Some((op, addr)) = line.split_once(' ') else { continue };
            let Ok(a) = u64::from_str_radix(addr.trim(), 16) else { continue };
            if a < b0 {
                continue; // feature / mask regions
            }
            match (op, a >= b1) {
                ("R", false) => reads_b0 += 1,
                ("R", true) => reads_b1 += 1,
                ("W", false) => writes_b0 += 1,
                ("W", true) => writes_b1 += 1,
                _ => {}
            }
        }
        assert!(writes_b0 > 0 && writes_b1 > 0, "both buffers must be written");
        assert!(reads_b0 > 0, "layer 2 must read what layer 1 wrote");
        assert_eq!(reads_b1, 0, "no layer reads the buffer it is writing");
    }

    // ------------------------------------------------------------------
    // Mini-batch sampling through the engine
    // ------------------------------------------------------------------

    #[test]
    fn sampled_run_reduces_traffic_and_is_deterministic() {
        let mut c = cfg_meaningful(Variant::T, 0.5);
        let g = c.build_graph();
        let full = run_sim(&c, &g);
        assert_eq!(full.sampler, "full");
        assert_eq!(full.sampled_edges, g.num_edges() as u64);
        c.sampler = SamplerKind::Neighbor;
        c.fanout = 8;
        let a = run_sim(&c, &g);
        let b = run_sim(&c, &g);
        assert_eq!(a.dram.reads, b.dram.reads);
        assert_eq!(a.dram.activations, b.dram.activations);
        assert_eq!(a.exec_ns, b.exec_ns);
        assert_eq!(a.sampler, "neighbor@8");
        assert!(a.sampled_edges < full.sampled_edges, "fanout must drop edges");
        assert!(a.dram.reads < full.dram.reads);
        assert!(
            a.compute_ns < full.compute_ns,
            "sampled drives must be charged for their subgraph"
        );
    }

    #[test]
    fn sampled_backward_follows_subset() {
        let mut c = cfg_meaningful(Variant::S, 0.5);
        c.backward = true;
        c.sampler = SamplerKind::Neighbor;
        c.fanout = 8;
        let g = c.build_graph();
        let m = run_sim(&c, &g);
        assert!(m.backward_reads > 0, "gradient reads must be attributed");
        assert_eq!(
            g.transpose_count(),
            0,
            "sampled backward must transpose the subgraph, not the full graph"
        );
        let mut full = c.clone();
        full.sampler = SamplerKind::Full;
        let f = run_sim(&full, &g);
        assert!(m.backward_reads < f.backward_reads, "subset gradient stream is smaller");
        assert_eq!(g.transpose_count(), 1, "full-batch backward shares the cached transpose");
    }

    #[test]
    fn sampled_epochs_accumulate_edges() {
        let mut c = cfg(Variant::S, 0.5);
        c.sampler = SamplerKind::Neighbor;
        c.fanout = 4;
        let g = c.build_graph();
        let one = run_sim(&c, &g);
        c.epochs = 2;
        let two = run_sim(&c, &g);
        // Per-vertex budgets make each epoch the same size, but every
        // epoch re-samples (the streams differ), so only the edge totals
        // double exactly.
        assert_eq!(two.sampled_edges, 2 * one.sampled_edges);
        assert!(two.dram.reads > one.dram.reads);
    }

    #[test]
    fn layerwise_single_entry_matches_uniform_fanout() {
        // `fanouts = [8]` must be metrics-identical to `fanout = 8`: the
        // layer-wise path's hop 0 shares the uniform path's seed stream,
        // and with one layer the schedules coincide.
        let mut uniform = cfg_meaningful(Variant::T, 0.5);
        uniform.sampler = SamplerKind::Neighbor;
        uniform.fanout = 8;
        let g = uniform.build_graph();
        let a = run_sim(&uniform, &g);
        let mut listed = uniform.clone();
        listed.fanouts = vec![8];
        let b = run_sim(&listed, &g);
        assert_eq!(a.dram.reads, b.dram.reads);
        assert_eq!(a.dram.activations, b.dram.activations);
        assert_eq!(a.exec_ns.to_bits(), b.exec_ns.to_bits());
        assert_eq!(a.sampled_edges, b.sampled_edges);
    }

    #[test]
    fn layerwise_fanouts_shrink_deeper_hops() {
        let mut c = cfg_meaningful(Variant::S, 0.5);
        c.sampler = SamplerKind::Neighbor;
        c.layers = 2;
        c.fanout = 8;
        c.fanouts = vec![8, 8];
        let g = c.build_graph();
        let equal = run_sim(&c, &g);
        let mut tapered = c.clone();
        tapered.fanouts = vec![8, 2];
        let t = run_sim(&tapered, &g);
        assert_eq!(t.sampler, "neighbor@8,2");
        // hop 0 budgets match, so layer-1 traffic is identical…
        assert_eq!(t.layer_reads[0], equal.layer_reads[0]);
        assert_eq!(t.sampled_edges, equal.sampled_edges, "layer-0 edge totals match");
        // …and the tapered second hop reads strictly less
        assert!(
            t.layer_reads[1] < equal.layer_reads[1],
            "fanout 2 hop reads {} !< fanout 8 hop reads {}",
            t.layer_reads[1],
            equal.layer_reads[1]
        );
        // determinism
        let t2 = run_sim(&tapered, &g);
        assert_eq!(t.dram.reads, t2.dram.reads);
        assert_eq!(t.exec_ns.to_bits(), t2.exec_ns.to_bits());
    }

    #[test]
    fn channel_partition_confines_activations() {
        use crate::dram::ChannelSet;
        let full = cfg_meaningful(Variant::T, 0.5);
        let mut part = full.clone();
        part.channels = Some(ChannelSet::parse("0-1").unwrap());
        let g = full.build_graph();
        let mf = run_sim(&full, &g);
        let mp = run_sim(&part, &g);
        // full run spreads across all 8 HBM channels
        assert!(mf.dram.channel_activations.iter().all(|&a| a > 0));
        // partitioned run never activates outside its subset
        assert_eq!(mp.dram.channel_activations.len(), 8);
        for (c, &acts) in mp.dram.channel_activations.iter().enumerate() {
            if c < 2 {
                assert!(acts > 0, "member channel {c} unused");
            } else {
                assert_eq!(acts, 0, "activation escaped to channel {c}");
            }
        }
        // two channels carry the traffic eight did: the bus serializes
        assert!(
            mp.mem_ns > mf.mem_ns,
            "partitioned mem {} !> full mem {}",
            mp.mem_ns,
            mf.mem_ns
        );
    }

    #[test]
    fn recorded_spans_cover_the_canonical_schedule() {
        use crate::telemetry::{SpanKind, TraceRecorder};
        let mut c = cfg(Variant::T, 0.5);
        c.epochs = 2;
        c.backward = true;
        let g = c.build_graph();
        let mut rec = TraceRecorder::new();
        let m = run_sim_recorded(&c, &g, &mut rec);
        let spans: Vec<_> = rec.spans().collect();
        // Per epoch: sample, forward, backward, write-back, mask WB.
        assert_eq!(spans.len(), 10);
        for e in 0..2u32 {
            let epoch: Vec<_> = spans.iter().filter(|s| s.epoch == e).collect();
            assert_eq!(epoch.len(), 5);
            assert_eq!(epoch[0].kind, SpanKind::Sample);
            assert_eq!(epoch[1].kind, SpanKind::Forward { layer: 0 });
            assert_eq!(epoch[2].kind, SpanKind::Backward);
            assert_eq!(epoch[3].kind, SpanKind::WriteBack);
            assert_eq!(epoch[4].kind, SpanKind::MaskWriteBack);
        }
        // Spans partition the run's cycle axis: each starts exactly
        // where the previous ended, and the deltas telescope to totals.
        for w in spans.windows(2) {
            assert_eq!(w[0].end_cycle, w[1].start_cycle);
            assert!(w[0].start_cycle <= w[0].end_cycle);
        }
        assert_eq!(rec.totals().reads, m.dram.reads);
        assert_eq!(rec.totals().writes, m.dram.writes);
        assert_eq!(rec.dropped(), 0);
    }

    #[test]
    fn preemption_at_every_boundary_conserves_metrics_exactly() {
        // Satellite property: park the engine at each schedule boundary
        // in turn, run a *different* simulation while parked, resume —
        // the final metrics must be bit-identical to the uninterrupted
        // run, and exactly one zero-width preempt marker must appear.
        use crate::telemetry::{SpanKind, TraceRecorder};
        let mut c = cfg_meaningful(Variant::T, 0.5);
        c.epochs = 2;
        c.backward = true;
        let g = c.build_graph();
        let nested_cfg = cfg(Variant::S, 0.3);
        let ng = nested_cfg.build_graph();

        // Baseline: the preemptible entry with a hook that always
        // declines (and counts the preemptible boundaries).
        let mut buf = Vec::new();
        let mut rec = TraceRecorder::new();
        let mut boundaries = 0usize;
        let base = run_sim_preemptible_with_buffer(
            &c,
            &g,
            &mut buf,
            &mut rec,
            0,
            true,
            &mut |cur, _chunk| {
                if !matches!(cur.next, NextStep::Finish) {
                    boundaries += 1;
                }
                false
            },
        );
        assert_eq!(boundaries, 10, "2 epochs x {{sample,fwd,bwd,wb,mask-wb}}");
        let base_spans = rec.spans().count();

        for k in 0..boundaries {
            let mut seen = 0usize;
            let mut rec = TraceRecorder::new();
            let mut buf = Vec::new();
            let mut logged = 0usize;
            let m = run_sim_preemptible_with_buffer(
                &c,
                &g,
                &mut buf,
                &mut rec,
                7,
                true,
                &mut |cur, chunk| {
                    logged += chunk.len();
                    if matches!(cur.next, NextStep::Finish) {
                        return false;
                    }
                    let fire = seen == k;
                    seen += 1;
                    if fire {
                        // a whole other simulation runs while this one
                        // sits parked on the stack
                        let _ = run_sim(&nested_cfg, &ng);
                    }
                    fire
                },
            );
            assert_eq!(m.dram.reads, base.dram.reads, "k={k}");
            assert_eq!(m.dram.writes, base.dram.writes, "k={k}");
            assert_eq!(m.dram.activations, base.dram.activations, "k={k}");
            assert_eq!(m.dram.row_hits, base.dram.row_hits, "k={k}");
            assert_eq!(m.dram.energy_pj.to_bits(), base.dram.energy_pj.to_bits(), "k={k}");
            assert_eq!(m.exec_ns.to_bits(), base.exec_ns.to_bits(), "k={k}");
            assert!(logged > 0, "request log must flow through the hook");
            let spans: Vec<_> = rec.spans().collect();
            let marks: Vec<_> =
                spans.iter().filter(|s| s.kind == SpanKind::Preempt).collect();
            assert_eq!(marks.len(), 1, "k={k}: exactly one preempt marker");
            assert_eq!(spans.len(), base_spans + 1, "k={k}");
            let p = marks[0];
            assert_eq!(p.start_cycle, p.end_cycle, "preempt markers are zero-width");
            assert_eq!(p.tenant, 7, "marker carries the tenant tag");
            assert_eq!(p.dram.reads + p.dram.writes + p.dram.activations, 0);
        }
    }

    #[test]
    fn multi_layer_is_deterministic() {
        let mut c = cfg(Variant::T, 0.5);
        c.layers = 3;
        c.backward = true;
        let g = c.build_graph();
        let x = run_sim(&c, &g);
        let y = run_sim(&c, &g);
        assert_eq!(x.dram.reads, y.dram.reads);
        assert_eq!(x.layer_reads, y.layer_reads);
        assert_eq!(x.backward_reads, y.backward_reads);
        assert_eq!(
            x.layer_reads.iter().sum::<u64>() + x.backward_reads,
            x.dram.reads,
            "every read must land in exactly one bucket"
        );
        assert_eq!(x.exec_ns, y.exec_ns);
    }
}
