//! Simulation driver: edge stream → (REC merge) → cache → LiGNN → DRAM.
//!
//! One run simulates a full layer-1 aggregation epoch (the paper's focus —
//! the initial aggregation dominates and deeper layers read on-chip
//! intermediates) plus the aggregation write-back, and reports
//! `exec = max(memory, compute)` since GCNTrain overlaps its datapaths.

use crate::accel::{EngineParams, Interleaver};
use crate::cache::LruCache;
use crate::config::SimConfig;
use crate::dram::energy::EnergyReport;
use crate::dram::DramModel;
use crate::graph::CsrGraph;
use crate::lignn::{AddressCalc, Burst, Criteria, Edge, LignnUnit, RecMerger};

use super::frfcfs::{FrFcfs, DEFAULT_DEPTH};
use super::metrics::Metrics;
use super::trace::TraceWriter;

/// Classification state per feature-read instance (`Burst::seq`).
#[derive(Clone, Copy, PartialEq, Eq)]
enum Served {
    None,
    Merged,
    Opened,
}

struct Run<'a> {
    cfg: &'a SimConfig,
    dram: DramModel,
    cache: LruCache,
    unit: LignnUnit,
    /// `Access`-way MLP interleaver for the non-LGT paths (LG-A/B); the
    /// LGT/REC variants issue in their own locality order instead.
    interleaver: Option<Interleaver>,
    /// Memory-controller scheduling window (part of the platform — applies
    /// to every variant).
    sched: FrFcfs,
    /// Optional DRAM burst trace capture.
    trace: Option<TraceWriter>,
    out: Vec<Burst>,
    served: Vec<Served>, // indexed by seq-1
    feat_hit: u64,
}

impl<'a> Run<'a> {
    fn new(cfg: &'a SimConfig) -> Run<'a> {
        let dram = DramModel::new(cfg.dram.config());
        let sched = FrFcfs::new(dram.config().channels, DEFAULT_DEPTH);
        let calc = AddressCalc::new(*dram.mapping(), cfg.feat_base, cfg.flen_bytes());
        let criteria = if cfg.channel_balance {
            Criteria::ChannelBalance
        } else {
            Criteria::Any
        };
        let unit = LignnUnit::new(cfg.variant, calc, cfg.alpha, cfg.range, criteria, cfg.seed);
        Run {
            cfg,
            dram,
            cache: LruCache::new(cfg.capacity),
            unit,
            interleaver: cfg.variant.interleaves().then(|| Interleaver::new(cfg.access)),
            sched,
            trace: cfg.trace_path.as_ref().map(|p| {
                TraceWriter::create(std::path::Path::new(p)).expect("creating trace file")
            }),
            out: Vec::with_capacity(8192),
            served: Vec::new(),
            feat_hit: 0,
        }
    }

    /// Process one aggregation edge: cache probe, then LiGNN, then issue
    /// whatever the unit emitted to DRAM (through the MLP interleaver for
    /// the non-LGT paths). `clustered` bypasses the interleaver — used for
    /// multi-edge REC groups, which the merger hardware issues as one
    /// clustered access sequence (§4.2).
    fn process(&mut self, src: u32, clustered: bool) {
        if self.cache.access(src) {
            self.feat_hit += 1;
            return;
        }
        match &mut self.interleaver {
            Some(_) if !clustered => {
                let mut feature = Vec::with_capacity(self.unit.calc().bursts_per_feature() as usize);
                self.unit.push_feature(src, &mut feature);
                let il = self.interleaver.as_mut().expect("interleaver present");
                il.push(feature, &mut self.out);
            }
            _ => {
                self.unit.push_feature(src, &mut self.out);
            }
        }
        self.issue();
    }

    /// Issue buffered bursts toward DRAM (through the memory controller's
    /// FR-FCFS window) in the unit's locality order.
    fn issue(&mut self) {
        let served = &mut self.served;
        let mut sink = |seq: u32, activated: bool| {
            let idx = seq as usize - 1;
            if idx >= served.len() {
                served.resize(idx + 1, Served::None);
            }
            if activated {
                served[idx] = Served::Opened;
            } else if served[idx] == Served::None {
                served[idx] = Served::Merged;
            }
        };
        for b in self.out.drain(..) {
            if let Some(t) = &mut self.trace {
                t.read(b.addr).expect("trace write");
            }
            self.sched.push(b, &mut self.dram, &mut sink);
        }
    }

    fn drain_sched(&mut self) {
        let served = &mut self.served;
        let mut sink = |seq: u32, activated: bool| {
            let idx = seq as usize - 1;
            if idx >= served.len() {
                served.resize(idx + 1, Served::None);
            }
            if activated {
                served[idx] = Served::Opened;
            } else if served[idx] == Served::None {
                served[idx] = Served::Merged;
            }
        };
        self.sched.flush(&mut self.dram, &mut sink);
    }

    /// Aggregation write-back: one output feature per vertex, streamed
    /// sequentially into a disjoint region (regular traffic, high row
    /// locality).
    fn write_back(&mut self, n: u32) {
        let flen_bytes = self.cfg.flen_bytes();
        let out_base = self.cfg.feat_base + (self.dram.mapping().capacity_bytes() >> 1);
        let mapping = *self.dram.mapping();
        for v in 0..n as u64 {
            let addr = out_base + v * flen_bytes;
            for a in mapping.bursts_for_range(addr, flen_bytes) {
                if let Some(t) = &mut self.trace {
                    t.write(a).expect("trace write");
                }
                self.dram.write_burst(a, 0);
            }
        }
    }

    /// §4.3: the dropout mask (1 bit per feature element, stored
    /// continuously like an edge feature) is written back for the backward
    /// pass. Sequential single-bit-per-element traffic — "good locality,
    /// in contrast to reading the feature data".
    fn write_masks(&mut self) {
        if !self.cfg.mask_writeback || self.cfg.alpha == 0.0 {
            return;
        }
        let mask_bytes = self.unit.stats.features_in * (self.cfg.flen as u64).div_ceil(8);
        let mask_base = self.cfg.feat_base + (self.dram.mapping().capacity_bytes() >> 2);
        let mapping = *self.dram.mapping();
        for a in mapping.bursts_for_range(mask_base, mask_bytes) {
            if let Some(t) = &mut self.trace {
                t.write(a).expect("trace write");
            }
            self.dram.write_burst(a, 0);
        }
    }
}

/// Run one full simulation; deterministic in `cfg.seed`.
pub fn run_sim(cfg: &SimConfig, graph: &CsrGraph) -> Metrics {
    cfg.validate().expect("invalid SimConfig");
    let mut run = Run::new(cfg);

    if cfg.variant.uses_merge() {
        // LG-T / LM: edges pass through the REC merger first (§4.2). The
        // REC table is bounded like the LGT's CAM (Table 3: 64 rows).
        // Multi-edge groups (same DRAM row class) issue clustered; the
        // singleton remainder flows through the engine's normal read path.
        let calc = *run.unit.calc();
        // REC CAM sized to the scheduling range (a class per pending edge
        // in the worst case, capped at 1024 — still a small edge table,
        // §5.2.4 prices it at ~0.01 mm²).
        let mut merger = RecMerger::new(calc, cfg.range, cfg.range.min(1024));

        let handle = |run: &mut Run, group: Vec<Edge>| {
            let clustered = group.len() > 1;
            for e in group {
                run.process(e.src, clustered);
            }
        };
        for (dst, src) in graph.edge_iter() {
            for group in merger.push(Edge { dst, src }) {
                handle(&mut run, group);
            }
        }
        for group in merger.flush() {
            handle(&mut run, group);
        }
    } else {
        for (_dst, src) in graph.edge_iter() {
            run.process(src, false);
        }
    }

    // Backward pass (optional): gradient aggregation walks the transposed
    // edge list, reading intermediate features with the same masked
    // pattern. LiGNN keeps the forward mask (§4.3) — requests for
    // already-dropped features never reappear — so the phase runs through
    // the same unit without fresh dropout decisions (α=0 semantics are
    // enforced by reusing the same unit whose δ balance persists).
    if cfg.backward {
        let transposed = graph.transpose();
        if cfg.variant.uses_merge() {
            let calc = *run.unit.calc();
            let mut merger = RecMerger::new(calc, cfg.range, cfg.range.min(1024));
            let handle = |run: &mut Run, group: Vec<Edge>| {
                let clustered = group.len() > 1;
                for e in group {
                    run.process(e.src, clustered);
                }
            };
            for (dst, src) in transposed.edge_iter() {
                for group in merger.push(Edge { dst, src }) {
                    handle(&mut run, group);
                }
            }
            for group in merger.flush() {
                handle(&mut run, group);
            }
        } else {
            for (_dst, src) in transposed.edge_iter() {
                run.process(src, false);
            }
        }
    }

    // Drain LiGNN residue and any in-flight interleaved reads, then the
    // write-back phase.
    let mut tail = Vec::new();
    run.unit.flush(&mut tail);
    run.out = tail;
    if let Some(il) = &mut run.interleaver {
        let mut drained = Vec::new();
        il.flush(&mut drained);
        run.out.extend(drained);
    }
    run.issue();
    run.drain_sched();
    run.write_back(graph.num_vertices() as u32);
    run.write_masks();
    if let Some(t) = run.trace.take() {
        t.finish().expect("flushing trace");
    }
    run.dram.flush_sessions();

    // Classify feature instances (hit counted at cache probe).
    let (mut feat_new, mut feat_merge, mut feat_dropped) = (0u64, 0u64, 0u64);
    for s in &run.served {
        match s {
            Served::Opened => feat_new += 1,
            Served::Merged => feat_merge += 1,
            Served::None => feat_dropped += 1,
        }
    }
    // Instances whose bursts were all dropped before any DRAM issue never
    // made it into `served`.
    feat_dropped += run.unit.stats.features_in - run.served.len() as u64;

    let engine = EngineParams::default();
    let mut compute_ns = engine.compute_ns(cfg.model, graph, cfg.flen, cfg.hidden);
    if cfg.backward {
        // backward ≈ 2× forward compute (input + weight gradients)
        compute_ns *= 3.0;
    }
    let mem_ns = run.dram.busy_ns();

    let energy = EnergyReport::from_counters(run.dram.config(), &run.dram.counters);
    Metrics {
        variant: cfg.variant.name().to_string(),
        graph: cfg.graph.name().to_string(),
        model: cfg.model.name().to_string(),
        dram_standard: cfg.dram.name().to_string(),
        alpha: cfg.alpha,
        exec_ns: mem_ns.max(compute_ns),
        mem_ns,
        compute_ns,
        unit: run.unit.stats.clone(),
        dram: run.dram.counters.clone(),
        energy,
        cache_hits: run.cache.hits(),
        cache_misses: run.cache.misses(),
        feat_hit: run.feat_hit,
        feat_new,
        feat_merge,
        feat_dropped,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GraphPreset, Variant};

    fn cfg(variant: Variant, alpha: f64) -> SimConfig {
        SimConfig {
            graph: GraphPreset::Tiny,
            variant,
            alpha,
            flen: 64,
            capacity: 256,
            access: 64,
            range: 64,
            ..Default::default()
        }
    }

    fn run(variant: Variant, alpha: f64) -> Metrics {
        let c = cfg(variant, alpha);
        let g = c.build_graph();
        run_sim(&c, &g)
    }

    #[test]
    fn baseline_alpha_zero_reads_all_misses() {
        let m = run(Variant::A, 0.0);
        // every cache miss expands to flen*4/32 bursts, all kept
        let bpf = 64 * 4 / 32;
        assert_eq!(m.dram.reads, m.cache_misses * bpf);
        assert_eq!(m.feat_dropped, 0);
        assert_eq!(m.unit.desired_elems, m.unit.total_elems);
    }

    #[test]
    fn variants_preserve_workload_identity() {
        // Same graph, same cache → same number of feature requests for
        // non-merge variants.
        let a = run(Variant::A, 0.5);
        let b = run(Variant::B, 0.5);
        let s = run(Variant::S, 0.5);
        assert_eq!(a.unit.features_in, b.unit.features_in);
        assert_eq!(a.unit.features_in, s.unit.features_in);
        assert_eq!(a.cache_hits + a.cache_misses, s.cache_hits + s.cache_misses);
    }

    /// Non-degenerate config: flen=256 (4 bursts per channel per feature)
    /// over the Small graph, so row-level locality has room to act.
    fn cfg_meaningful(variant: Variant, alpha: f64) -> SimConfig {
        SimConfig {
            graph: GraphPreset::Small,
            variant,
            alpha,
            flen: 256,
            capacity: 1024,
            access: 256,
            range: 256,
            ..Default::default()
        }
    }

    fn run_meaningful(variant: Variant, alpha: f64) -> Metrics {
        let c = cfg_meaningful(variant, alpha);
        let g = c.build_graph();
        run_sim(&c, &g)
    }

    #[test]
    fn lgt_variant_reduces_activations_vs_baseline() {
        let a = run_meaningful(Variant::A, 0.5);
        let s = run_meaningful(Variant::S, 0.5);
        assert!(
            s.dram.activations < a.dram.activations,
            "LG-S acts {} !< LG-A acts {}",
            s.dram.activations,
            a.dram.activations
        );
        assert!(s.dram.reads < a.dram.reads);
    }

    #[test]
    fn merge_at_least_matches_lgt_alone() {
        // On top of the LGT's grouping the REC merger adds little at this
        // scale (the LGT already captures most same-row coalescing within
        // its scheduling range); assert parity within noise. The isolated
        // merge effect is asserted by `merge_only_beats_interleaved_baseline`.
        let s = run_meaningful(Variant::S, 0.5);
        let t = run_meaningful(Variant::T, 0.5);
        let ratio = t.dram.activations as f64 / s.dram.activations as f64;
        assert!(ratio < 1.05, "LG-T acts {} vs LG-S acts {}", t.dram.activations, s.dram.activations);
    }

    #[test]
    fn merge_only_beats_interleaved_baseline() {
        // §5.4's LM vs NM: the merge-only variant at α=0 against the plain
        // interleaved engine at α=0 — merging alone must cut activations
        // and time (paper: 1.3–1.6× speedup).
        let nm = run_meaningful(Variant::A, 0.0);
        let lm = run_meaningful(Variant::M, 0.0);
        assert!(
            (lm.dram.activations as f64) < 0.9 * nm.dram.activations as f64,
            "LM acts {} !< NM acts {}",
            lm.dram.activations,
            nm.dram.activations
        );
        assert!(lm.exec_ns < nm.exec_ns);
        // merging never drops anything
        assert_eq!(lm.unit.bursts_kept, lm.unit.bursts_in);
    }

    #[test]
    fn exec_time_monotone_in_alpha_for_row_variants() {
        let lo = run(Variant::S, 0.1);
        let hi = run(Variant::S, 0.8);
        assert!(hi.exec_ns < lo.exec_ns);
    }

    #[test]
    fn breakdown_partitions_features() {
        let m = run(Variant::T, 0.3);
        assert_eq!(
            m.feat_new + m.feat_merge + m.feat_dropped,
            m.unit.features_in,
            "breakdown must partition DRAM-bound features"
        );
        assert_eq!(m.feat_hit, m.cache_hits);
    }

    #[test]
    fn backward_pass_adds_traffic_keeps_ratios() {
        let mut fwd = cfg_meaningful(Variant::T, 0.5);
        let mut both = cfg_meaningful(Variant::T, 0.5);
        both.backward = true;
        let g = fwd.build_graph();
        let f = run_sim(&fwd, &g);
        let b = run_sim(&both, &g);
        assert!(b.dram.reads > f.dram.reads, "backward must add reads");
        assert!(b.exec_ns > f.exec_ns);
        // and the variant still drops at the configured rate overall
        let kept = b.unit.bursts_kept as f64 / b.unit.bursts_in as f64;
        assert!((kept - 0.5).abs() < 0.08, "kept {kept}");
        let _ = (&mut fwd, &mut both);
    }

    #[test]
    fn trace_capture_replays_identically() {
        let dir = std::env::temp_dir().join("lignn-driver-trace");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.trace");
        let mut c = cfg(Variant::S, 0.5);
        c.trace_path = Some(path.to_string_lossy().into_owned());
        let g = c.build_graph();
        let live = run_sim(&c, &g);
        let (counters, _) = crate::sim::trace::replay(
            &path,
            crate::dram::DramModel::new(c.dram.config()),
        )
        .unwrap();
        // Replay through a fresh device (no FR-FCFS window) preserves the
        // transaction counts; activations match because the trace records
        // post-scheduling issue order.
        assert_eq!(counters.reads, live.dram.reads);
        assert_eq!(counters.writes, live.dram.writes);
    }

    #[test]
    fn deterministic_across_runs() {
        let x = run(Variant::T, 0.5);
        let y = run(Variant::T, 0.5);
        assert_eq!(x.dram.reads, y.dram.reads);
        assert_eq!(x.dram.activations, y.dram.activations);
        assert_eq!(x.exec_ns, y.exec_ns);
    }

    #[test]
    fn writes_present_for_all_variants() {
        for v in [Variant::A, Variant::B, Variant::R, Variant::S, Variant::T] {
            let m = run(v, 0.5);
            let g = cfg(v, 0.5).build_graph();
            let bpf = 64 * 4 / 32;
            let agg_writes = g.num_vertices() as u64 * bpf;
            // aggregation write-back plus the §4.3 mask write-back
            let mask_writes = (m.unit.features_in * (64u64).div_ceil(8)).div_ceil(32);
            assert_eq!(m.dram.writes, agg_writes + mask_writes, "{v:?}");
        }
    }

    #[test]
    fn mask_writeback_toggle() {
        let mut with = cfg(Variant::S, 0.5);
        with.mask_writeback = true;
        let mut without = cfg(Variant::S, 0.5);
        without.mask_writeback = false;
        let g = with.build_graph();
        let a = run_sim(&with, &g);
        let b = run_sim(&without, &g);
        assert!(a.dram.writes > b.dram.writes);
        assert_eq!(a.dram.reads, b.dram.reads);
    }

    #[test]
    fn channel_balance_criteria_runs() {
        let mut c = cfg(Variant::S, 0.5);
        c.channel_balance = true;
        let g = c.build_graph();
        let m = run_sim(&c, &g);
        assert!(m.exec_ns > 0.0);
        assert_eq!(
            m.unit.bursts_in,
            m.unit.bursts_kept + m.unit.bursts_filter_dropped + m.unit.bursts_row_dropped
        );
    }
}
