#!/usr/bin/env python3
"""Validate a lignn spatial DRAM heatmap against the run's JSON metrics.

Usage: check_heatmap.py <heatmap.json> <metrics.json>
       check_heatmap.py --compare <natural_heatmap.json> <reordered_heatmap.json> [K]

Validate mode checks (all hard failures):
  - the heatmap parses; the three grids are channels x banks rectangles
  - grid conservation: the activation grid sums to the run's
    `activations` total, per channel to `channel_activations[ch]`, the
    hit grid to `row_hits` — every ACT/hit landed in exactly one
    (channel, bank) cell
  - the grids' own `total_*` fields agree with their cell sums, and
    conflicts never exceed activations (globally and per cell)
  - sketch conservation: `sketch_total` equals `activations` (every ACT
    passed through the Space-Saving sketch)
  - hot rows: at most `topk`, sorted by activation count descending,
    `acts >= err >= 0`, shares in [0, 1] and summing to <= 1 + eps,
    decoded channel/bank indices inside the device geometry, region one
    of features/mask/intermediate/other, and feature rows carry a
    non-inverted vertex range
  - reuse histogram rows reference in-range banks with count >= 1 and
    p50 <= p95 <= max

Compare mode checks that a reordered (islandized) run's hot-row
concentration did not worsen: the sum of ABSOLUTE activation counts over
the top-K hot rows must be <= the natural run's, and total ACTs must
drop or hold. Absolute counts, not shares — islandization concentrates
the (much smaller) ACT total into fewer rows, so top-K *share* rises
even as every row's actual activation count falls.

Stdlib only — runs on any CI python3.
"""

import json
import sys

EPS = 1e-9

fails = []


def check(cond, msg):
    if not cond:
        fails.append(msg)


def grid_sum(grid):
    return sum(sum(row) for row in grid)


def main(heatmap_path, metrics_path):
    with open(heatmap_path) as f:
        hm = json.load(f)
    with open(metrics_path) as f:
        metrics = json.load(f)

    channels = hm.get("channels")
    banks = hm.get("banks")
    check(isinstance(channels, (int, float)) and channels >= 1, f"bad channels {channels!r}")
    check(isinstance(banks, (int, float)) and banks >= 1, f"bad banks {banks!r}")
    channels, banks = int(channels), int(banks)

    grids = {}
    for name in ("acts", "hits", "conflicts"):
        g = hm.get(name)
        check(isinstance(g, list) and len(g) == channels, f"{name}: not {channels} channels")
        for c, row in enumerate(g or []):
            check(
                isinstance(row, list) and len(row) == banks,
                f"{name}[{c}]: not {banks} banks",
            )
            check(all(v >= 0 for v in row), f"{name}[{c}]: negative cell")
        grids[name] = g or []

    # Conservation against the run's own metrics (simulate --json).
    acts_sum = grid_sum(grids["acts"])
    hits_sum = grid_sum(grids["hits"])
    conflicts_sum = grid_sum(grids["conflicts"])
    check(
        acts_sum == metrics.get("activations"),
        f"acts grid sum {acts_sum} != metrics activations {metrics.get('activations')}",
    )
    check(
        hits_sum == metrics.get("row_hits"),
        f"hits grid sum {hits_sum} != metrics row_hits {metrics.get('row_hits')}",
    )
    chan_acts = metrics.get("channel_activations", [])
    check(
        len(chan_acts) == channels,
        f"metrics channel_activations has {len(chan_acts)} channels, heatmap {channels}",
    )
    for c, expect in enumerate(chan_acts[:channels]):
        got = sum(grids["acts"][c])
        check(got == expect, f"channel {c}: grid acts {got} != metrics {expect}")

    # Internal consistency of the document.
    check(acts_sum == hm.get("total_acts"), f"total_acts {hm.get('total_acts')} != {acts_sum}")
    check(hits_sum == hm.get("total_hits"), f"total_hits {hm.get('total_hits')} != {hits_sum}")
    check(
        conflicts_sum == hm.get("total_conflicts"),
        f"total_conflicts {hm.get('total_conflicts')} != {conflicts_sum}",
    )
    check(conflicts_sum <= acts_sum, f"conflicts {conflicts_sum} exceed acts {acts_sum}")
    for c in range(channels):
        for b in range(banks):
            check(
                grids["conflicts"][c][b] <= grids["acts"][c][b],
                f"cell ({c},{b}): conflicts {grids['conflicts'][c][b]} "
                f"> acts {grids['acts'][c][b]}",
            )

    # Sketch conservation: every ACT fed the hot-row sketch.
    check(
        hm.get("sketch_total") == metrics.get("activations"),
        f"sketch_total {hm.get('sketch_total')} != activations "
        f"{metrics.get('activations')}",
    )

    # Hot rows: bounded, ordered, bounds valid, attribution well-formed.
    topk = int(hm.get("topk", 0))
    rows = hm.get("hot_rows", [])
    check(len(rows) <= topk, f"{len(rows)} hot rows exceed topk {topk}")
    regions = {"features", "mask", "intermediate", "other"}
    share_sum = 0.0
    prev = None
    for i, r in enumerate(rows):
        acts, err = r.get("acts"), r.get("err")
        check(acts is not None and err is not None, f"hot row {i}: missing acts/err")
        check(acts >= err >= 0, f"hot row {i}: bound acts={acts} err={err}")
        if prev is not None:
            check(prev >= acts, f"hot row {i}: not sorted desc ({prev} then {acts})")
        prev = acts
        check(0 <= r.get("channel", -1) < channels, f"hot row {i}: channel out of range")
        share = r.get("share", -1.0)
        check(0.0 <= share <= 1.0, f"hot row {i}: share {share} outside [0,1]")
        share_sum += share
        check(r.get("region") in regions, f"hot row {i}: region {r.get('region')!r}")
        if r.get("region") == "features":
            fv, lv = r.get("first_vertex"), r.get("last_vertex")
            check(
                fv is not None and lv is not None and fv <= lv,
                f"hot row {i}: inverted vertex range {fv}..{lv}",
            )
    check(share_sum <= 1.0 + EPS, f"hot-row shares sum to {share_sum} > 1")

    # Reuse rows: in-range banks, sane percentile ordering.
    for i, r in enumerate(hm.get("reuse", [])):
        check(0 <= r.get("channel", -1) < channels, f"reuse {i}: channel out of range")
        check(0 <= r.get("bank", -1) < banks, f"reuse {i}: bank out of range")
        check(r.get("count", 0) >= 1, f"reuse {i}: empty histogram exported")
        p50, p95, mx = r.get("p50", 0), r.get("p95", 0), r.get("max", 0)
        check(p50 <= p95 <= mx, f"reuse {i}: percentiles disordered {p50}/{p95}/{mx}")

    if fails:
        for msg in fails:
            print(f"FAIL: {msg}", file=sys.stderr)
        sys.exit(1)
    print(
        f"heatmap OK: {channels}x{banks} grid conserves {acts_sum} ACTs "
        f"({hits_sum} hits, {conflicts_sum} conflicts), {len(rows)} hot rows, "
        f"{len(hm.get('reuse', []))} reuse histograms"
    )


def topk_acts(hm, k):
    rows = hm.get("hot_rows", [])
    return sum(r.get("acts", 0) for r in rows[:k])


def compare(natural_path, reordered_path, k):
    with open(natural_path) as f:
        nat = json.load(f)
    with open(reordered_path) as f:
        reo = json.load(f)

    nat_total, reo_total = nat.get("total_acts", 0), reo.get("total_acts", 0)
    check(
        reo_total <= nat_total,
        f"reordered total ACTs {reo_total} > natural {nat_total}",
    )
    nat_topk, reo_topk = topk_acts(nat, k), topk_acts(reo, k)
    check(
        reo_topk <= nat_topk,
        f"reordered top-{k} hot-row ACTs {reo_topk} > natural {nat_topk}",
    )

    if fails:
        for msg in fails:
            print(f"FAIL: {msg}", file=sys.stderr)
        sys.exit(1)
    print(
        f"reorder compare OK: total ACTs {nat_total} -> {reo_total} "
        f"({reo_total / max(nat_total, 1):.3f}x), top-{k} hot-row ACTs "
        f"{nat_topk} -> {reo_topk} ({reo_topk / max(nat_topk, 1):.3f}x)"
    )


if __name__ == "__main__":
    if len(sys.argv) >= 2 and sys.argv[1] == "--compare":
        if len(sys.argv) not in (4, 5):
            print(__doc__, file=sys.stderr)
            sys.exit(2)
        compare(sys.argv[2], sys.argv[3], int(sys.argv[4]) if len(sys.argv) == 5 else 8)
    elif len(sys.argv) == 3:
        main(sys.argv[1], sys.argv[2])
    else:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
