#!/usr/bin/env python3
"""Validate a lignn Perfetto trace against the run's JSON metrics.

Usage: check_trace.py <trace.json> <metrics.json> <metrics.prom>

Checks (all hard failures):
  - the trace parses and `traceEvents` is non-empty
  - every complete ("X") event has ts >= 0 and dur >= 0
  - every phase span is contained in its epoch's container event
    (matched by args.epoch, not by position)
  - preempt markers (QoS phase-boundary parks) are zero-width, carry
    zero counter deltas, and nest in their epoch like any other span —
    so preempted traces still telescope to the run totals
  - epoch containers are pairwise non-overlapping (touching is fine)
  - per-span reads/writes/activations sum exactly to the trace's
    `lignnTotals` side object AND to the simulate-mode metrics JSON
  - the Prometheus snapshot is line-well-formed and its headline
    counters agree with the metrics JSON

Ring evictions (dropped_spans > 0) are a WARNING, not a failure: long
serving sessions legitimately outgrow the ring, and `lignnTotals` comes
from the recorder's running totals, so the totals-vs-metrics agreement
stays exact regardless. The per-span telescoping check is skipped in
that case (evicted spans can no longer sum to the totals); the dropped
count is exported as `lignn_telemetry_dropped_spans_total` so
dashboards can alert on sustained loss.

Stdlib only — runs on any CI python3.
"""

import json
import re
import sys

# Cycle stamps are converted to float microseconds on export; allow one
# ULP-ish slop on the containment comparison only. Counter sums are
# integers carried in f64 and must match exactly.
EPS = 1e-6

fails = []


def check(cond, msg):
    if not cond:
        fails.append(msg)


def main(trace_path, metrics_path, prom_path):
    with open(trace_path) as f:
        trace = json.load(f)
    with open(metrics_path) as f:
        metrics = json.load(f)
    with open(prom_path) as f:
        prom = f.read()

    events = trace.get("traceEvents", [])
    check(len(events) > 0, "traceEvents is empty")

    epochs = {}   # epoch id -> (ts, ts+dur)
    phases = []   # (name, epoch id, ts, ts+dur, args)
    counters = 0
    for e in events:
        ph = e.get("ph")
        if ph == "C":
            counters += 1
            continue
        check(ph == "X", f"unexpected event ph {ph!r}")
        ts, dur = e.get("ts"), e.get("dur")
        check(isinstance(ts, (int, float)) and ts >= 0, f"{e.get('name')}: bad ts {ts!r}")
        check(isinstance(dur, (int, float)) and dur >= 0, f"{e.get('name')}: bad dur {dur!r}")
        args = e.get("args", {})
        epoch = args.get("epoch")
        check(epoch is not None, f"{e.get('name')}: X event without args.epoch")
        if e.get("cat") == "epoch":
            check(epoch not in epochs, f"duplicate epoch container {epoch}")
            epochs[epoch] = (ts, ts + dur)
        else:
            check(e.get("cat") == "phase", f"unexpected X category {e.get('cat')!r}")
            phases.append((e.get("name"), epoch, ts, ts + dur, args))

    check(len(epochs) > 0, "no epoch containers")
    check(len(phases) > 0, "no phase spans")

    # Preempt markers: zero-width, zero-delta — they may sit anywhere
    # inside their epoch (the generic containment check below covers
    # nesting), but must never carry time or counters, or the telescoping
    # sums would double-count the parked work.
    preempts = [p for p in phases if p[0] == "preempt"]
    for name, epoch, start, end, args in preempts:
        check(end == start, f"preempt marker at ts {start} has nonzero width {end - start}")
        for key in ("reads", "writes", "activations", "row_hits"):
            check(
                args.get(key, 0) == 0,
                f"preempt marker at ts {start} carries {key}={args.get(key)}",
            )

    # Spans nest: each phase inside its own epoch's container.
    for name, epoch, start, end, _ in phases:
        container = epochs.get(epoch)
        check(container is not None, f"{name}: no container for epoch {epoch}")
        if container:
            lo, hi = container
            check(
                start >= lo - EPS and end <= hi + EPS,
                f"{name}: [{start}, {end}] escapes epoch {epoch} [{lo}, {hi}]",
            )

    # Epoch containers don't overlap (touching boundaries are fine —
    # a zero-length sample span can sit exactly on the seam).
    ordered = sorted(epochs.items(), key=lambda kv: kv[1][0])
    for (ea, (_, end_a)), (eb, (start_b, _)) in zip(ordered, ordered[1:]):
        check(end_a <= start_b + EPS, f"epochs {ea} and {eb} overlap")

    # Per-span deltas sum to the exported totals, exactly. Ring
    # evictions demote this to a warning: the surviving spans can no
    # longer telescope, but the totals themselves are still exact.
    totals = trace.get("lignnTotals", {})
    dropped = totals.get("dropped_spans", 0)
    if dropped != 0:
        print(
            f"WARN: {dropped} spans evicted from the recorder ring — "
            "skipping per-span telescoping check",
            file=sys.stderr,
        )
    else:
        for key in ("reads", "writes", "activations", "row_hits"):
            span_sum = sum(p[4].get(key, 0) for p in phases)
            check(
                span_sum == totals.get(key),
                f"span {key} sum {span_sum} != lignnTotals {totals.get(key)}",
            )
    # ...and to the run's own metrics JSON (simulate --json output).
    for key in ("reads", "writes", "activations", "row_hits"):
        check(
            metrics.get(key) == totals.get(key),
            f"metrics {key} {metrics.get(key)} != lignnTotals {totals.get(key)}",
        )
    check(
        abs(totals.get("span_energy_pj", 0) - metrics.get("energy_pj", -1)) < 1e-9,
        f"span energy {totals.get('span_energy_pj')} != metrics {metrics.get('energy_pj')}",
    )

    # Prometheus snapshot: well-formed lines, headline counters agree.
    sample_re = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*\{[^}]*\} -?[0-9.eE+-]+$")
    values = {}
    for line in prom.splitlines():
        if not line or line.startswith("#"):
            continue
        check(sample_re.match(line), f"malformed prometheus line: {line!r}")
        name = line.split("{", 1)[0]
        values.setdefault(name, 0.0)
        values[name] += float(line.rsplit(" ", 1)[1])
    for prom_name, key in [
        ("lignn_dram_reads_total", "reads"),
        ("lignn_dram_writes_total", "writes"),
        ("lignn_dram_activations_total", "activations"),
        ("lignn_phase_activations_total", "activations"),
        ("lignn_channel_activations_total", "activations"),
    ]:
        check(
            values.get(prom_name) == metrics.get(key),
            f"{prom_name} {values.get(prom_name)} != metrics {key} {metrics.get(key)}",
        )

    if fails:
        for msg in fails:
            print(f"FAIL: {msg}", file=sys.stderr)
        sys.exit(1)
    print(
        f"trace OK: {len(phases)} phase spans in {len(epochs)} epochs "
        f"({len(preempts)} preempt markers), {counters} counter samples, "
        f"sums match metrics"
    )


if __name__ == "__main__":
    if len(sys.argv) != 4:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    main(sys.argv[1], sys.argv[2], sys.argv[3])
