#!/usr/bin/env python3
"""Gate bench results against committed baselines.

Usage: bench_diff.py <baseline_dir> <fresh_dir>

Compares the perf-smoke JSON artifacts (BENCH_hotpath.json,
BENCH_serve.json, BENCH_interference.json — the files CI copies into
smoke/) against the same-named files under the baseline directory
(bench_baselines/ in the repo), and fails on a >15% regression of:

  - the hotpath run-coalescing streak speedup
    (per_s of "dram.read_run(streak)" over "dram.read_burst(sequential)",
    and its profiled twin when both sides carry it)
  - the serve bench's end-to-end `jobs_per_sec` headline
  - the qos_partition bench's partitioned/shared `*_elapsed_ms`
    (elapsed is lower-is-better; the other two are higher-is-better)
  - the reorder bench's islandized/natural activation ratios and the
    4-shard peak-residency ratio (all lower-is-better same-run ratios:
    a rise means reordering or sharding lost ground)

A missing baseline file or key is a WARNING and passes — that is the
seeding path: the first CI run after this gate lands produces the
artifacts that get committed as the baselines. CI wall-clock noise is
why the bar sits at 15%, well above run-to-run jitter.

Stdlib only — runs on any CI python3.
"""

import json
import os
import sys

THRESHOLD = 0.15

fails = []
warns = []


def load(dirname, fname):
    path = os.path.join(dirname, fname)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def gate(label, base, fresh, lower_is_better=False):
    """Record a failure if fresh regressed >15% against base."""
    if base is None or fresh is None:
        warns.append(f"{label}: missing value (base={base}, fresh={fresh}) — skipped")
        return
    if base <= 0:
        warns.append(f"{label}: non-positive baseline {base} — skipped")
        return
    if lower_is_better:
        ratio = fresh / base - 1.0  # positive = slower = worse
    else:
        ratio = 1.0 - fresh / base  # positive = lower throughput = worse
    direction = "rose" if lower_is_better else "dropped"
    line = f"{label}: {base:.4g} -> {fresh:.4g} ({direction} {abs(ratio) * 100:.1f}%)"
    if ratio > THRESHOLD:
        fails.append(line)
    else:
        print(f"ok {line}")


def hotpath_speedups(rows):
    """Streak speedups derivable from the hotpath rows, by label."""
    if rows is None:
        return None
    per_s = {r.get("stage"): r.get("per_s") for r in rows}
    seq = per_s.get("dram.read_burst(sequential)")
    out = {}
    for label, stage in [
        ("streak_speedup", "dram.read_run(streak)"),
        ("profiled_streak_speedup", "dram.read_run(streak, profiled)"),
    ]:
        if seq and per_s.get(stage):
            out[label] = per_s[stage] / seq
    return out


def main(baseline_dir, fresh_dir):
    if not os.path.isdir(baseline_dir):
        print(
            f"WARN: baseline dir {baseline_dir!r} missing — seeding run, gate passes",
            file=sys.stderr,
        )
        return

    # Hotpath: the run-coalescing speedup is the number the PRs defend;
    # raw per_s of a single stage is too runner-dependent to gate, the
    # speedup is a same-run ratio and stable.
    base_hp = hotpath_speedups(load(baseline_dir, "BENCH_hotpath.json"))
    fresh_hp = hotpath_speedups(load(fresh_dir, "BENCH_hotpath.json"))
    if base_hp is None:
        warns.append("BENCH_hotpath.json: no baseline — skipped")
    elif fresh_hp is None:
        fails.append("BENCH_hotpath.json missing from the fresh run")
    else:
        for label in base_hp:
            gate(f"hotpath {label}", base_hp.get(label), fresh_hp.get(label))

    base_sv = load(baseline_dir, "BENCH_serve.json")
    fresh_sv = load(fresh_dir, "BENCH_serve.json")
    if base_sv is None:
        warns.append("BENCH_serve.json: no baseline — skipped")
    elif fresh_sv is None:
        fails.append("BENCH_serve.json missing from the fresh run")
    else:
        gate(
            "serve jobs_per_sec",
            base_sv.get("jobs_per_sec"),
            fresh_sv.get("jobs_per_sec"),
        )

    base_if = load(baseline_dir, "BENCH_interference.json")
    fresh_if = load(fresh_dir, "BENCH_interference.json")
    if base_if is None:
        warns.append("BENCH_interference.json: no baseline — skipped")
    elif fresh_if is None:
        fails.append("BENCH_interference.json missing from the fresh run")
    else:
        for key in ("partitioned_elapsed_ms", "shared_elapsed_ms"):
            gate(
                f"interference {key}",
                base_if.get(key),
                fresh_if.get(key),
                lower_is_better=True,
            )

    base_ro = load(baseline_dir, "BENCH_reorder.json")
    fresh_ro = load(fresh_dir, "BENCH_reorder.json")
    if base_ro is None:
        warns.append("BENCH_reorder.json: no baseline — skipped")
    elif fresh_ro is None:
        fails.append("BENCH_reorder.json missing from the fresh run")
    else:
        for key in ("act_ratio_a0", "act_ratio_a5", "shard_peak_ratio"):
            gate(
                f"reorder {key}",
                base_ro.get(key),
                fresh_ro.get(key),
                lower_is_better=True,
            )

    for msg in warns:
        print(f"WARN: {msg}", file=sys.stderr)
    if fails:
        for msg in fails:
            print(f"FAIL: {msg}", file=sys.stderr)
        sys.exit(1)
    print("bench diff OK: no regression beyond 15%")


if __name__ == "__main__":
    if len(sys.argv) != 3:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    main(sys.argv[1], sys.argv[2])
