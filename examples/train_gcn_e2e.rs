//! End-to-end driver: train a 2-layer GNN through the full three-layer
//! stack — Pallas masked-aggregation kernel (L1) inside the JAX train step
//! (L2), AOT-lowered to HLO and executed from Rust over PJRT (L3) — with
//! dropout masks generated at DRAM-burst/row granularity by the same
//! address-mapping code the simulator uses.
//!
//! Before training it runs the matching 2-layer `SimEngine` workload on
//! the same planted graph, so the accuracy numbers print next to the
//! DRAM traffic the accelerator would see for this exact model depth
//! (per-layer read counts — layer 1 dominating is measured, not assumed).
//!
//! Reproduces Table 5 (burst/row dropout keeps accuracy) and logs the loss
//! curve. Run `make artifacts` first. Requires the `pjrt` build feature.
//!
//! Usage: train_gcn_e2e [--model gcn|sage|gin] [--epochs N] [--alpha A]
//!                      [--mask element|burst|row] [--table5] [--no-sim]

use std::path::Path;

use lignn::config::{GraphPreset, SchedulePreset, SimConfig, Variant};
use lignn::sim::run_sim;
use lignn::trainer::{train, Dataset, MaskKind, TrainConfig};
use lignn::util::error::{Error, Result};

/// Simulate the 2-layer training step's aggregation traffic (forward ×2
/// layers + transposed gradient phase) on the dataset's graph.
fn simulate_traffic(ds: &Dataset, alpha: f64) {
    let mut cfg = SimConfig {
        graph: GraphPreset::Planted,
        variant: Variant::T,
        alpha,
        flen: ds.f,
        // The trained models' combination width is narrower than the
        // input features — layer-2 intermediates are read at this width.
        hidden: 16,
        capacity: 256,
        access: 32,
        range: 256,
        ..Default::default()
    };
    SchedulePreset::TWO_LAYER_TRAINING.apply(&mut cfg);
    if cfg.validate().is_err() || !ds.f.is_power_of_two() {
        // e.g. a feature width the address calculator cannot tile
        eprintln!("(skipping traffic simulation: dataset shape not simulable)");
        return;
    }
    let m = run_sim(&cfg, &ds.graph);
    let shares = m.layer_read_shares();
    println!(
        "simulated 2-layer training traffic (LG-T, α={alpha}): {} reads, {} activations",
        m.dram.reads, m.dram.activations
    );
    for (i, (r, s)) in m.layer_reads.iter().zip(&shares).enumerate() {
        println!(
            "  layer {} aggregation: {r} DRAM reads ({:.1}% of forward)",
            i + 1,
            s * 100.0
        );
    }
    println!("  backward (gradient) pass: {} DRAM reads", m.backward_reads);
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let value = |i: usize, flag: &str| -> Result<&String> {
        args.get(i + 1).ok_or_else(|| Error::msg(format!("{flag} needs a value")))
    };
    let mut cfg = TrainConfig::default();
    let mut table5 = false;
    let mut sim = true;
    let mut i = 1;
    while i < args.len() {
        match args[i].as_str() {
            "--model" => {
                cfg.model = value(i, "--model")?.clone();
                i += 2;
            }
            "--epochs" => {
                cfg.epochs = value(i, "--epochs")?.parse().map_err(Error::msg)?;
                i += 2;
            }
            "--alpha" => {
                cfg.alpha = value(i, "--alpha")?.parse().map_err(Error::msg)?;
                i += 2;
            }
            "--mask" => {
                cfg.mask = value(i, "--mask")?.parse().map_err(Error::msg)?;
                i += 2;
            }
            "--table5" => {
                table5 = true;
                i += 1;
            }
            "--no-sim" => {
                sim = false;
                i += 1;
            }
            other => return Err(Error::msg(format!("unknown flag {other}"))),
        }
    }

    let dir = Path::new("artifacts");
    let ds = Dataset::planted(1024, 64, 8, 7);
    println!(
        "dataset: planted partition |V|={} |E|={} classes={} (train {:.0}%)",
        ds.n,
        ds.graph.num_edges(),
        ds.c,
        100.0 * ds.train_mask.iter().sum::<f32>() as f64 / ds.n as f64
    );
    if sim {
        simulate_traffic(&ds, cfg.alpha);
    }

    if table5 {
        // Table 5: burst & row dropout across droprates, vs the no-dropout
        // and element baselines.
        println!("\nTable 5 — effect of burst/row dropout on model accuracy ({})", cfg.model);
        println!("{:>10} {:>6} {:>10} {:>10} {:>12}", "mask", "α", "train-acc", "test-acc", "final-loss");
        for mask in [MaskKind::Element, MaskKind::Burst, MaskKind::Row] {
            for alpha in [0.0, 0.1, 0.2, 0.5] {
                let c = TrainConfig { alpha, mask, ..cfg.clone() };
                let r = train(dir, &c, &ds)?;
                println!(
                    "{:>10} {:>6.1} {:>10.3} {:>10.3} {:>12.4}",
                    format!("{mask:?}"),
                    alpha,
                    r.train_accuracy,
                    r.test_accuracy,
                    r.losses.last().unwrap()
                );
            }
        }
        return Ok(());
    }

    println!(
        "training {} for {} epochs, α={}, mask={:?}",
        cfg.model, cfg.epochs, cfg.alpha, cfg.mask
    );
    let r = train(dir, &cfg, &ds)?;
    for (e, loss) in r.losses.iter().enumerate() {
        if e % 10 == 0 || e + 1 == r.losses.len() {
            println!("epoch {e:>4}  loss {loss:.4}");
        }
    }
    println!(
        "train accuracy {:.3}, test accuracy {:.3}",
        r.train_accuracy, r.test_accuracy
    );
    Ok(())
}
