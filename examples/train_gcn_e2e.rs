//! End-to-end driver: train a 2-layer GNN through the full three-layer
//! stack — Pallas masked-aggregation kernel (L1) inside the JAX train step
//! (L2), AOT-lowered to HLO and executed from Rust over PJRT (L3) — with
//! dropout masks generated at DRAM-burst/row granularity by the same
//! address-mapping code the simulator uses.
//!
//! Reproduces Table 5 (burst/row dropout keeps accuracy) and logs the loss
//! curve. Run `make artifacts` first.
//!
//! Usage: train_gcn_e2e [--model gcn|sage|gin] [--epochs N] [--alpha A]
//!                      [--mask element|burst|row] [--table5]

use std::path::Path;

use lignn::trainer::{train, Dataset, MaskKind, TrainConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let mut cfg = TrainConfig::default();
    let mut table5 = false;
    let mut i = 1;
    while i < args.len() {
        match args[i].as_str() {
            "--model" => {
                cfg.model = args[i + 1].clone();
                i += 2;
            }
            "--epochs" => {
                cfg.epochs = args[i + 1].parse()?;
                i += 2;
            }
            "--alpha" => {
                cfg.alpha = args[i + 1].parse()?;
                i += 2;
            }
            "--mask" => {
                cfg.mask = args[i + 1].parse().map_err(anyhow::Error::msg)?;
                i += 2;
            }
            "--table5" => {
                table5 = true;
                i += 1;
            }
            other => anyhow::bail!("unknown flag {other}"),
        }
    }

    let dir = Path::new("artifacts");
    let ds = Dataset::planted(1024, 64, 8, 7);
    println!(
        "dataset: planted partition |V|={} |E|={} classes={} (train {:.0}%)",
        ds.n,
        ds.graph.num_edges(),
        ds.c,
        100.0 * ds.train_mask.iter().sum::<f32>() as f64 / ds.n as f64
    );

    if table5 {
        // Table 5: burst & row dropout across droprates, vs the no-dropout
        // and element baselines.
        println!("\nTable 5 — effect of burst/row dropout on model accuracy ({})", cfg.model);
        println!("{:>10} {:>6} {:>10} {:>10} {:>12}", "mask", "α", "train-acc", "test-acc", "final-loss");
        for mask in [MaskKind::Element, MaskKind::Burst, MaskKind::Row] {
            for alpha in [0.0, 0.1, 0.2, 0.5] {
                let c = TrainConfig { alpha, mask, ..cfg.clone() };
                let r = train(dir, &c, &ds)?;
                println!(
                    "{:>10} {:>6.1} {:>10.3} {:>10.3} {:>12.4}",
                    format!("{mask:?}"),
                    alpha,
                    r.train_accuracy,
                    r.test_accuracy,
                    r.losses.last().unwrap()
                );
            }
        }
        return Ok(());
    }

    println!(
        "training {} for {} epochs, α={}, mask={:?}",
        cfg.model, cfg.epochs, cfg.alpha, cfg.mask
    );
    let r = train(dir, &cfg, &ds)?;
    for (e, loss) in r.losses.iter().enumerate() {
        if e % 10 == 0 || e + 1 == r.losses.len() {
            println!("epoch {e:>4}  loss {loss:.4}");
        }
    }
    println!(
        "train accuracy {:.3}, test accuracy {:.3}",
        r.train_accuracy, r.test_accuracy
    );
    Ok(())
}
