//! Quickstart: run LG-A (baseline) and LG-T at α=0.5 on the LJ-sim graph
//! with HBM, print the headline metrics (speedup, DRAM access reduction,
//! row-activation reduction) — the paper's abstract numbers.

use lignn::config::{GraphPreset, SimConfig, Variant};
use lignn::sim::{run_sim, SweepPlan, SweepRunner};

fn main() {
    let mut cfg = SimConfig {
        graph: GraphPreset::Small,
        ..Default::default()
    };
    // parse optional --graph lj/or/pa/small and --alpha
    let args: Vec<String> = std::env::args().collect();
    for w in args.windows(2) {
        match w[0].as_str() {
            "--graph" => cfg.graph = w[1].parse().expect("bad graph"),
            "--alpha" => cfg.alpha = w[1].parse().expect("bad alpha"),
            "--flen" => cfg.flen = w[1].parse().expect("bad flen"),
            "--capacity" => cfg.capacity = w[1].parse().expect("bad capacity"),
            "--range" => cfg.range = w[1].parse().expect("bad range"),
            "--access" => cfg.access = w[1].parse().expect("bad access"),
            _ => {}
        }
    }
    let graph = cfg.build_graph();
    println!(
        "graph {}: |V|={} |E|={}",
        cfg.graph.name(),
        graph.num_vertices(),
        graph.num_edges()
    );

    // All five Table-3 variants as one sweep plan: the runner shares the
    // graph across points and recycles per-worker burst buffers.
    let plan = SweepPlan::variants(
        &cfg,
        &[Variant::A, Variant::B, Variant::R, Variant::S, Variant::T],
    );
    let results = SweepRunner::new(&graph).run(&plan);
    for m in &results {
        println!("{}", m.summary());
    }

    let mut base = cfg.clone();
    base.variant = Variant::A;
    base.alpha = 0.0;
    let b = run_sim(&base, &graph);
    // LG-T at cfg.alpha already ran as the sweep's last point — reuse it.
    let m = results.into_iter().last().expect("plan was non-empty");
    println!(
        "\nLG-T @ α={:.1} vs non-dropout: speedup {:.2}x, DRAM access -{:.0}%, row activation -{:.0}%",
        cfg.alpha,
        m.speedup_vs(&b),
        (1.0 - m.access_ratio_vs(&b)) * 100.0,
        (1.0 - m.activation_ratio_vs(&b)) * 100.0
    );
}
