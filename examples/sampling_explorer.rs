//! Sampling explorer: how much DRAM locality does the *sampler* buy,
//! before LiGNN's dropout/merge even runs?
//!
//! Compares full-batch, uniform-neighbor and locality-aware sampling at
//! one fanout on the plain engine (LG-A, α=0), printing subgraph
//! row-group locality next to the DRAM traffic each epoch produced.
//!
//!     cargo run --release --example sampling_explorer -- --fanout 8

use lignn::config::{GraphPreset, SamplerKind, SimConfig, Variant};
use lignn::dram::AddressMapping;
use lignn::sim::{SweepPlan, SweepRunner};

fn main() {
    let mut cfg = SimConfig {
        graph: GraphPreset::Small,
        variant: Variant::A,
        alpha: 0.0,
        flen: 256,
        capacity: 1024,
        access: 32,
        range: 512,
        ..Default::default()
    };
    cfg.fanout = 8;
    let args: Vec<String> = std::env::args().collect();
    for w in args.windows(2) {
        match w[0].as_str() {
            "--graph" => cfg.graph = w[1].parse().expect("bad graph"),
            "--fanout" => cfg.fanout = w[1].parse().expect("bad fanout"),
            "--alpha" => cfg.alpha = w[1].parse().expect("bad alpha"),
            "--variant" => cfg.variant = w[1].parse().expect("bad variant"),
            _ => {}
        }
    }
    let graph = cfg.build_graph();
    let mapping = AddressMapping::new(&cfg.dram.config());
    let group = mapping.vertices_per_row_group(cfg.flen_bytes()) as usize;
    println!(
        "graph {}: |V|={} |E|={}  ({} vertices per {}-byte row group)",
        cfg.graph.name(),
        graph.num_vertices(),
        graph.num_edges(),
        group,
        mapping.row_group_bytes(),
    );

    let plan = SweepPlan::samplers(&cfg, &SamplerKind::ALL);
    let results = SweepRunner::new(&graph).run(&plan);
    for (kind, m) in SamplerKind::ALL.iter().zip(&results) {
        let mut point = cfg.clone();
        point.sampler = *kind;
        let sub = point.build_sampler().sample(&graph, 0);
        let loc = sub.graph().row_group_locality(group);
        println!(
            "{:<12} edges={:<7} coverage={:>5.1}%  rg-rate={:.3} groups/v={:.2}  \
             reads={:<7} acts={:<7} cache-hits={}",
            m.sampler,
            sub.num_edges(),
            sub.edge_coverage() * 100.0,
            loc.same_group_rate(),
            loc.mean_groups_per_vertex,
            m.dram.reads,
            m.dram.activations,
            m.cache_hits,
        );
    }

    let uni = &results[1];
    let loc = &results[2];
    println!(
        "\nlocality vs neighbor @ fanout {}: activations ×{:.2}, reads ×{:.2}, exec ×{:.2}",
        cfg.fanout,
        loc.dram.activations as f64 / uni.dram.activations.max(1) as f64,
        loc.dram.reads as f64 / uni.dram.reads.max(1) as f64,
        loc.exec_ns / uni.exec_ns,
    );
}
