//! DRAM-standard explorer: run the same workload (LJ-sim / GCN) across all
//! eight Table-4 standards and print how LiGNN's gains track the geometry
//! (bursts per row, burst size, channel count) — the extended version of
//! the paper's Figs 13/14 exploration.
//!
//! Usage: dram_explorer [--alpha A] [--graph lj|or|pa|small|tiny]

use lignn::config::{SimConfig, Variant};
use lignn::dram::DramStandardKind;
use lignn::sim::run_sim;
use lignn::util::benchkit::print_table;

fn main() {
    let mut cfg = SimConfig { graph: "small".parse().unwrap(), ..Default::default() };
    let args: Vec<String> = std::env::args().collect();
    for w in args.windows(2) {
        match w[0].as_str() {
            "--alpha" => cfg.alpha = w[1].parse().expect("bad alpha"),
            "--graph" => cfg.graph = w[1].parse().expect("bad graph"),
            _ => {}
        }
    }
    let graph = cfg.build_graph();
    println!(
        "workload: {} GCN, α={:.1}, |V|={} |E|={}",
        cfg.graph.name(),
        cfg.alpha,
        graph.num_vertices(),
        graph.num_edges()
    );

    let standards = [
        DramStandardKind::Ddr3,
        DramStandardKind::Ddr4,
        DramStandardKind::Gddr5,
        DramStandardKind::Gddr6,
        DramStandardKind::Lpddr4,
        DramStandardKind::Lpddr5,
        DramStandardKind::Hbm,
        DramStandardKind::Hbm2,
    ];
    let mut rows = Vec::new();
    for dram in standards {
        let geom = dram.config();
        let mut base = cfg.clone();
        base.dram = dram;
        base.variant = Variant::A;
        base.alpha = 0.0;
        let b = run_sim(&base, &graph);
        let mut t = cfg.clone();
        t.dram = dram;
        t.variant = Variant::T;
        let m = run_sim(&t, &graph);
        rows.push(vec![
            dram.name().to_string(),
            format!("{}ch", geom.channels),
            format!("{}B", geom.burst_bytes()),
            format!("{}", geom.bursts_per_row()),
            format!("{:.2}ms", b.exec_ns / 1e6),
            format!("{:.2}ms", m.exec_ns / 1e6),
            format!("{:.2}x", m.speedup_vs(&b)),
            format!("-{:.0}%", (1.0 - m.access_ratio_vs(&b)) * 100.0),
            format!("-{:.0}%", (1.0 - m.activation_ratio_vs(&b)) * 100.0),
            format!("{:.1}mJ", m.energy.total_pj / 1e9),
        ]);
    }
    print_table(
        &format!("LG-T @ α={:.1} vs non-dropout across DRAM standards", cfg.alpha),
        &[
            "standard", "channels", "burst", "bursts/row", "base", "LG-T", "speedup", "access",
            "activation", "energy",
        ],
        &rows,
    );
}
