//! Merge analysis (§5.4): LM (merge-only) vs NM (plain interleaved
//! engine), with the row-session distribution and the hit/new/merge
//! access breakdown — the interactive companion to `benches/fig15_19_merge`.
//!
//! Usage: merge_analysis [--graph small|lj] [--flen N] [--capacity N]
//!                       [--range N] [--access N]

use lignn::config::{SimConfig, Variant};
use lignn::sim::run_sim;
use lignn::util::benchkit::print_table;

fn main() {
    let mut cfg = SimConfig {
        graph: "small".parse().unwrap(),
        alpha: 0.0,
        flen: 512,
        capacity: 1024,
        access: 1024,
        range: 1024,
        ..Default::default()
    };
    let args: Vec<String> = std::env::args().collect();
    for w in args.windows(2) {
        match w[0].as_str() {
            "--graph" => cfg.graph = w[1].parse().expect("bad graph"),
            "--flen" => cfg.flen = w[1].parse().expect("bad flen"),
            "--capacity" => cfg.capacity = w[1].parse().expect("bad capacity"),
            "--range" => cfg.range = w[1].parse().expect("bad range"),
            "--access" => cfg.access = w[1].parse().expect("bad access"),
            _ => {}
        }
    }
    let graph = cfg.build_graph();
    println!(
        "workload: {} GCN HBM, flen={} capacity={} range={} access={}",
        cfg.graph.name(),
        cfg.flen,
        cfg.capacity,
        cfg.range,
        cfg.access
    );

    let mut nm_cfg = cfg.clone();
    nm_cfg.variant = Variant::A;
    let nm = run_sim(&nm_cfg, &graph);
    let mut lm_cfg = cfg.clone();
    lm_cfg.variant = Variant::M;
    let lm = run_sim(&lm_cfg, &graph);

    let total = |m: &lignn::Metrics| (m.feat_hit + m.feat_new + m.feat_merge).max(1) as f64;
    let rows = vec![
        vec![
            "NM".into(),
            format!("{:.3}ms", nm.exec_ns / 1e6),
            format!("{}", nm.dram.activations),
            format!("{:.2}", nm.dram.mean_session()),
            format!("{:.1}%", 100.0 * nm.feat_hit as f64 / total(&nm)),
            format!("{:.1}%", 100.0 * nm.feat_new as f64 / total(&nm)),
            format!("{:.1}%", 100.0 * nm.feat_merge as f64 / total(&nm)),
        ],
        vec![
            "LM".into(),
            format!("{:.3}ms", lm.exec_ns / 1e6),
            format!("{}", lm.dram.activations),
            format!("{:.2}", lm.dram.mean_session()),
            format!("{:.1}%", 100.0 * lm.feat_hit as f64 / total(&lm)),
            format!("{:.1}%", 100.0 * lm.feat_new as f64 / total(&lm)),
            format!("{:.1}%", 100.0 * lm.feat_merge as f64 / total(&lm)),
        ],
    ];
    print_table(
        "LM vs NM (no dropout)",
        &["config", "exec", "activations", "mean session", "hit", "new", "merge"],
        &rows,
    );
    println!(
        "\nLM speedup {:.2}x, activation ratio {:.3}",
        lm.speedup_vs(&nm),
        lm.activation_ratio_vs(&nm)
    );

    // session size distribution (Fig 16 view)
    let mut rows = Vec::new();
    for size in 1..=8usize {
        rows.push(vec![
            size.to_string(),
            nm.dram.session_hist[size].to_string(),
            lm.dram.session_hist[size].to_string(),
        ]);
    }
    print_table("Row-session size distribution", &["size", "NM", "LM"], &rows);
}
