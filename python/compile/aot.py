"""AOT compile path: lower L2 train/predict functions to HLO *text*.

HLO text (NOT ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Emits, for each model in {gcn, sage, gin}:
    artifacts/train_step_<model>.hlo.txt
    artifacts/predict_<model>.hlo.txt
plus ``artifacts/manifest.json`` recording the exact input/output ABI the
Rust trainer must honour (shapes, dtypes, parameter order, constants).

Run via ``make artifacts`` — python never runs on the request path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def input_specs(model, kind, n, f, h, c):
    """Flat (name, shape) list matching the exported function's signature."""
    names_shapes = [(nm, sh) for nm, sh in M.param_shapes(model, f, h, c)]
    if kind == "train_step":
        names_shapes += [
            ("adj_raw", (n, n)),
            ("x", (n, f)),
            ("mask", (n, f)),
            ("scale", (1,)),
            ("labels_onehot", (n, c)),
            ("train_mask", (n,)),
        ]
    else:  # predict
        names_shapes += [("adj_raw", (n, n)), ("x", (n, f))]
    return names_shapes


def output_specs(model, kind, n, f, h, c):
    if kind == "train_step":
        return [(nm, sh) for nm, sh in M.param_shapes(model, f, h, c)] + [
            ("loss", ())
        ]
    return [("logits", (n, c))]


def lower_one(model, kind, n, f, h, c, lr):
    fn = M.make_train_step(model, lr) if kind == "train_step" else M.make_predict(model)
    specs = [_spec(sh) for _, sh in input_specs(model, kind, n, f, h, c)]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--n-nodes", type=int, default=M.N_NODES)
    ap.add_argument("--n-features", type=int, default=M.N_FEATURES)
    ap.add_argument("--n-hidden", type=int, default=M.N_HIDDEN)
    ap.add_argument("--n-classes", type=int, default=M.N_CLASSES)
    ap.add_argument("--lr", type=float, default=M.LEARNING_RATE)
    ap.add_argument("--models", nargs="*", default=list(M.MODELS))
    args = ap.parse_args()

    n, f, h, c = args.n_nodes, args.n_features, args.n_hidden, args.n_classes
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "constants": {
            "n_nodes": n,
            "n_features": f,
            "n_hidden": h,
            "n_classes": c,
            "lr": args.lr,
            "gin_eps": M.GIN_EPS,
        },
        "artifacts": [],
    }

    for model in args.models:
        for kind in ("train_step", "predict"):
            fname = f"{kind}_{model}.hlo.txt"
            path = os.path.join(args.out_dir, fname)
            text = lower_one(model, kind, n, f, h, c, args.lr)
            with open(path, "w") as fp:
                fp.write(text)
            manifest["artifacts"].append(
                {
                    "model": model,
                    "kind": kind,
                    "file": fname,
                    "inputs": [
                        {"name": nm, "shape": list(sh), "dtype": "f32"}
                        for nm, sh in input_specs(model, kind, n, f, h, c)
                    ],
                    "outputs": [
                        {"name": nm, "shape": list(sh), "dtype": "f32"}
                        for nm, sh in output_specs(model, kind, n, f, h, c)
                    ],
                    "n_params": len(M.PARAM_SPECS[model]),
                }
            )
            print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as fp:
        json.dump(manifest, fp, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
