"""L1 Pallas kernel: masked neighbor aggregation.

This is the compute hot-spot of the paper's target workload — the GNN
aggregation phase — expressed as a Pallas kernel so the whole L2 training
step lowers into one HLO module. The kernel computes

    out = adj @ (x * mask) * scale

tiled over row-blocks of ``adj`` so each grid step touches one
[BLOCK_N, N] tile of the adjacency, the full [N, F] feature/mask panel
(features are the dense-matrix side of GCNTrain's SpMM; the panel is the
analogue of the accelerator's on-chip dense-tile buffer), and produces one
[BLOCK_N, F] output tile.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the feature panel streams
HBM→VMEM via the BlockSpec index maps; the dropout mask is applied
element-wise in VMEM (VPU) before the MXU matmul; burst-granular masks zero
aligned lane groups, mirroring the aligned-burst sparsity LiGNN creates in
DRAM. ``interpret=True`` everywhere — the CPU PJRT plugin cannot execute
Mosaic custom-calls; real-TPU perf is estimated analytically in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-block of the adjacency processed per grid step. 128 matches both the
# MXU systolic dimension and the f32 VMEM sublane*lane tile multiple.
BLOCK_N = 128


def _masked_aggregate_kernel(adj_ref, x_ref, mask_ref, scale_ref, o_ref):
    """One grid step: o = adj_block @ (x * mask) * scale.

    adj_ref:   [BLOCK_N, N]  row-block of normalized adjacency
    x_ref:     [N, F]        full feature panel (resident per step)
    mask_ref:  [N, F]        keep mask (1.0 / 0.0)
    scale_ref: [1, 1]        1/(1-alpha) rescale (SMEM-style scalar)
    o_ref:     [BLOCK_N, F]  output tile
    """
    masked = x_ref[...] * mask_ref[...]
    acc = jnp.dot(adj_ref[...], masked, preferred_element_type=jnp.float32)
    o_ref[...] = acc * scale_ref[0, 0]


def masked_aggregate(adj, x, mask, scale, block_n=BLOCK_N):
    """Pallas-tiled ``adj @ (x * mask) * scale``.

    Pads N up to a multiple of ``block_n`` when needed (zero rows/cols are
    exact for this computation). ``scale`` may be a python float or a scalar
    array.

    Args:
      adj:  [N, N] f32 normalized adjacency.
      x:    [N, F] f32 features.
      mask: [N, F] f32 keep mask.
      scale: scalar — dropout rescale 1/(1-alpha).
      block_n: row-block size (must stay MXU-aligned; default 128).

    Returns:
      [N, F] f32 aggregated features.
    """
    n, f = x.shape
    if adj.shape != (n, n):
        raise ValueError(f"adj shape {adj.shape} incompatible with x {x.shape}")
    if mask.shape != (n, f):
        raise ValueError(f"mask shape {mask.shape} incompatible with x {x.shape}")

    n_pad = (-n) % block_n
    if n_pad:
        adj = jnp.pad(adj, ((0, n_pad), (0, n_pad)))
        x = jnp.pad(x, ((0, n_pad), (0, 0)))
        mask = jnp.pad(mask, ((0, n_pad), (0, 0)))
    np_, fp = x.shape

    scale_arr = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    grid = (np_ // block_n,)

    out = pl.pallas_call(
        _masked_aggregate_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, np_), lambda i: (i, 0)),  # adj row-block
            pl.BlockSpec((np_, fp), lambda i: (0, 0)),       # feature panel
            pl.BlockSpec((np_, fp), lambda i: (0, 0)),       # mask panel
            pl.BlockSpec((1, 1), lambda i: (0, 0)),          # scale scalar
        ],
        out_specs=pl.BlockSpec((block_n, fp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, fp), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(adj, x, mask, scale_arr)

    return out[:n] if n_pad else out


@functools.partial(jax.jit, static_argnames=("block_n",))
def masked_aggregate_jit(adj, x, mask, scale, block_n=BLOCK_N):
    """Jitted wrapper used by the pytest suite."""
    return masked_aggregate(adj, x, mask, scale, block_n=block_n)
