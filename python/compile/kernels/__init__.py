# L1: Pallas kernel(s) for the paper's compute hot-spot.
from .aggregate import masked_aggregate, masked_aggregate_jit, BLOCK_N  # noqa: F401
from .ref import (  # noqa: F401
    degree_normalize_ref,
    masked_aggregate_ref,
    mean_normalize_ref,
)
