"""Pure-jnp correctness oracles for the LiGNN kernels.

These are the ground-truth implementations the Pallas kernels in
``aggregate.py`` are validated against (pytest + hypothesis sweeps in
``python/tests/``). They are deliberately written in the most obvious way
possible — no tiling, no tricks — so that a mismatch always indicts the
kernel, not the oracle.
"""

import jax.numpy as jnp


def masked_aggregate_ref(adj, x, mask, scale):
    """Neighbor aggregation with a (burst/row-granular) dropout mask.

    Computes ``adj @ (x * mask) * scale`` — the aggregation phase of a GNN
    layer where LiGNN has dropped part of the feature reads. ``mask`` is the
    per-(vertex, element) keep mask produced by the Rust dropout generator
    (element / burst / DRAM-row granularity all reduce to this dense form),
    and ``scale`` is the compute-unit-side 1/(1-alpha) rescale the paper
    assigns to the compute engine rather than LiGNN (§4.3).

    Args:
      adj:   [N, N] float — normalized adjacency (Â = D^-1/2 (A+I) D^-1/2
             for GCN, row-mean for SAGE, plain A for GIN).
      x:     [N, F] float — vertex features.
      mask:  [N, F] float — 1.0 keep / 0.0 drop.
      scale: scalar float — 1/(1-alpha) dropout rescale.

    Returns:
      [N, F] aggregated features.
    """
    return adj @ (x * mask) * scale


def degree_normalize_ref(adj_raw):
    """Symmetric GCN normalization with self loops: D^-1/2 (A+I) D^-1/2."""
    n = adj_raw.shape[0]
    a = adj_raw + jnp.eye(n, dtype=adj_raw.dtype)
    deg = a.sum(axis=1)
    d_inv_sqrt = jnp.where(deg > 0, 1.0 / jnp.sqrt(deg), 0.0)
    return a * d_inv_sqrt[:, None] * d_inv_sqrt[None, :]


def mean_normalize_ref(adj_raw):
    """Row-mean normalization (GraphSAGE mean aggregator), self excluded."""
    deg = adj_raw.sum(axis=1)
    d_inv = jnp.where(deg > 0, 1.0 / deg, 0.0)
    return adj_raw * d_inv[:, None]
