# pytest: L2 model — shapes, gradients, training dynamics, dropout rescale.
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

N, F, H, C = 96, 16, 12, 4


@pytest.fixture(scope="module")
def case():
    k = jax.random.PRNGKey(42)
    k0, k1, k2, k3 = jax.random.split(k, 4)
    adj = (jax.random.uniform(k0, (N, N)) < 0.05).astype(jnp.float32)
    adj = jnp.maximum(adj, adj.T)  # undirected
    x = jax.random.normal(k1, (N, F), jnp.float32)
    labels = jax.random.randint(k2, (N,), 0, C)
    onehot = jax.nn.one_hot(labels, C, dtype=jnp.float32)
    train_mask = (jax.random.uniform(k3, (N,)) < 0.5).astype(jnp.float32)
    return adj, x, onehot, train_mask


@pytest.mark.parametrize("model", M.MODELS)
def test_forward_shapes(model, case):
    adj, x, onehot, train_mask = case
    params = M.init_params(model, jax.random.PRNGKey(0), F, H, C)
    logits = M.forward(model, params, adj, x, jnp.ones_like(x), 1.0)
    assert logits.shape == (N, C)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("model", M.MODELS)
def test_param_shapes_match_specs(model):
    params = M.init_params(model, jax.random.PRNGKey(1), F, H, C)
    for p, (name, shape) in zip(params, M.param_shapes(model, F, H, C)):
        assert p.shape == shape, name


@pytest.mark.parametrize("model", M.MODELS)
def test_train_step_reduces_loss(model, case):
    adj, x, onehot, train_mask = case
    step = jax.jit(M.make_train_step(model, lr=0.1))
    params = M.init_params(model, jax.random.PRNGKey(2), F, H, C)
    mask = jnp.ones_like(x)
    scale = jnp.asarray([1.0], jnp.float32)
    losses = []
    for _ in range(30):
        out = step(*params, adj, x, mask, scale, onehot, train_mask)
        params, loss = list(out[:-1]), out[-1]
        losses.append(float(loss))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], f"loss did not decrease: {losses[0]} -> {losses[-1]}"


@pytest.mark.parametrize("model", M.MODELS)
def test_gradients_finite_under_dropout(model, case):
    adj, x, onehot, train_mask = case
    params = M.init_params(model, jax.random.PRNGKey(3), F, H, C)
    alpha = 0.5
    mask = (jax.random.uniform(jax.random.PRNGKey(4), (N, F)) >= alpha).astype(
        jnp.float32
    )
    grads = jax.grad(M.loss_fn)(
        params, model, adj, x, mask, 1.0 / (1.0 - alpha), onehot, train_mask
    )
    for g in grads:
        assert np.isfinite(np.asarray(g)).all()


def test_dropout_rescale_preserves_aggregate_expectation(case):
    """E[mask * scale] = 1 — the 1/(1-a) rescale keeps aggregation unbiased."""
    adj, x, _, _ = case
    alpha = 0.5
    acc = jnp.zeros((N, F))
    trials = 200
    for i in range(trials):
        m = (jax.random.uniform(jax.random.PRNGKey(i), (N, F)) >= alpha).astype(
            jnp.float32
        )
        acc = acc + m / (1.0 - alpha)
    mean_mask = acc / trials
    np.testing.assert_allclose(np.asarray(mean_mask).mean(), 1.0, atol=0.02)


def test_masked_cross_entropy_ignores_non_train(case):
    adj, x, onehot, _ = case
    logits = jax.random.normal(jax.random.PRNGKey(5), (N, C))
    m1 = jnp.zeros((N,)).at[:10].set(1.0)
    l1 = M.masked_cross_entropy(logits, onehot, m1)
    # Perturbing logits outside the mask must not change the loss.
    logits2 = logits.at[50:].add(3.0)
    l2 = M.masked_cross_entropy(logits2, onehot, m1)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_predict_matches_forward_no_dropout(case):
    adj, x, _, _ = case
    params = M.init_params("gcn", jax.random.PRNGKey(6), F, H, C)
    pred = M.make_predict("gcn")
    (logits,) = pred(*params, adj, x)
    ref = M.forward("gcn", params, adj, x, jnp.ones_like(x), 1.0)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref), rtol=1e-5)


@pytest.mark.parametrize("model", M.MODELS)
def test_train_step_is_pure_sgd(model, case):
    """step(params) == params - lr * grad — verified against jax.grad."""
    adj, x, onehot, train_mask = case
    lr = 0.07
    step = M.make_train_step(model, lr=lr)
    params = M.init_params(model, jax.random.PRNGKey(7), F, H, C)
    mask = jnp.ones_like(x)
    scale = jnp.asarray([1.0], jnp.float32)
    out = step(*params, adj, x, mask, scale, onehot, train_mask)
    new_params = out[:-1]
    grads = jax.grad(M.loss_fn)(
        params, model, adj, x, mask, scale, onehot, train_mask
    )
    for p, g, npm in zip(params, grads, new_params):
        np.testing.assert_allclose(
            np.asarray(npm), np.asarray(p - lr * g), rtol=1e-5, atol=1e-6
        )
