# pytest: AOT path — HLO text is parseable-shaped, manifest ABI is coherent,
# and (when artifacts exist) the emitted files match the current ABI.
import json
import os

import pytest

from compile import model as M
from compile.aot import input_specs, lower_one, output_specs

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.parametrize("model", M.MODELS)
@pytest.mark.parametrize("kind", ["train_step", "predict"])
def test_lower_emits_hlo_text(model, kind):
    text = lower_one(model, kind, 64, 8, 8, 3, 0.05)
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # Text interchange only — serialized protos are rejected downstream.
    assert "\x00" not in text


@pytest.mark.parametrize("model", M.MODELS)
def test_abi_input_output_counts(model):
    n_params = len(M.PARAM_SPECS[model])
    ins = input_specs(model, "train_step", 64, 8, 8, 3)
    outs = output_specs(model, "train_step", 64, 8, 8, 3)
    assert len(ins) == n_params + 6  # params + adj,x,mask,scale,labels,train_mask
    assert len(outs) == n_params + 1  # params' + loss
    pins = input_specs(model, "predict", 64, 8, 8, 3)
    pouts = output_specs(model, "predict", 64, 8, 8, 3)
    assert len(pins) == n_params + 2
    assert pouts == [("logits", (64, 3))]


def test_param_count_in_hlo_matches_abi():
    text = lower_one("gcn", "predict", 32, 4, 4, 2, 0.05)
    # ENTRY signature must carry exactly n_params + 2 parameters.
    entry = [l for l in text.splitlines() if l.startswith("ENTRY")][0]
    assert entry.count("parameter") == 0  # names not in signature line
    n_expected = len(M.PARAM_SPECS["gcn"]) + 2
    assert text.count(" = f32[") >= n_expected  # at least the inputs appear


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_matches_current_abi():
    with open(os.path.join(ART_DIR, "manifest.json")) as fp:
        man = json.load(fp)
    consts = man["constants"]
    n, f, h, c = (
        consts["n_nodes"],
        consts["n_features"],
        consts["n_hidden"],
        consts["n_classes"],
    )
    by_key = {(a["model"], a["kind"]): a for a in man["artifacts"]}
    for model in M.MODELS:
        for kind in ("train_step", "predict"):
            a = by_key[(model, kind)]
            want = [
                {"name": nm, "shape": list(sh), "dtype": "f32"}
                for nm, sh in input_specs(model, kind, n, f, h, c)
            ]
            assert a["inputs"] == want, (model, kind)
            assert os.path.exists(os.path.join(ART_DIR, a["file"]))
            with open(os.path.join(ART_DIR, a["file"])) as fh:
                head = fh.read(64)
            assert head.startswith("HloModule")
