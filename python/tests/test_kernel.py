# pytest: Pallas kernel vs pure-jnp ref — the CORE correctness signal.
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    BLOCK_N,
    masked_aggregate,
    masked_aggregate_jit,
    masked_aggregate_ref,
)

RTOL = 2e-5
ATOL = 2e-5


def _rand_case(seed, n, f, density=0.5, alpha=0.5):
    k0, k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 4)
    adj = (jax.random.uniform(k0, (n, n)) < density).astype(jnp.float32)
    x = jax.random.normal(k1, (n, f), jnp.float32)
    mask = (jax.random.uniform(k2, (n, f)) >= alpha).astype(jnp.float32)
    scale = 1.0 / (1.0 - alpha) if alpha < 1.0 else 1.0
    return adj, x, mask, scale


def _check(adj, x, mask, scale, block_n=BLOCK_N):
    out = masked_aggregate(adj, x, mask, scale, block_n=block_n)
    ref = masked_aggregate_ref(adj, x, mask, scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=RTOL, atol=ATOL)


class TestMaskedAggregateBasic:
    def test_block_aligned(self):
        _check(*_rand_case(0, 256, 64))

    def test_unaligned_n_pads(self):
        # N not a multiple of BLOCK_N exercises the zero-pad path.
        _check(*_rand_case(1, 200, 48))

    def test_single_block(self):
        _check(*_rand_case(2, BLOCK_N, 32))

    def test_tiny(self):
        _check(*_rand_case(3, 3, 2))

    def test_mask_all_ones_is_plain_matmul(self):
        adj, x, _, _ = _rand_case(4, 100, 16)
        ones = jnp.ones_like(x)
        out = masked_aggregate(adj, x, ones, 1.0)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(adj @ x), rtol=RTOL, atol=ATOL
        )

    def test_mask_all_zero_is_zero(self):
        adj, x, _, _ = _rand_case(5, 64, 8)
        out = masked_aggregate(adj, x, jnp.zeros_like(x), 2.0)
        assert np.abs(np.asarray(out)).max() == 0.0

    def test_scale_applied(self):
        adj, x, mask, _ = _rand_case(6, 64, 8)
        out1 = np.asarray(masked_aggregate(adj, x, mask, 1.0))
        out3 = np.asarray(masked_aggregate(adj, x, mask, 3.0))
        np.testing.assert_allclose(out3, 3.0 * out1, rtol=RTOL, atol=ATOL)

    def test_scale_as_array(self):
        adj, x, mask, _ = _rand_case(7, 64, 8)
        out_f = np.asarray(masked_aggregate(adj, x, mask, 2.0))
        out_a = np.asarray(masked_aggregate(adj, x, mask, jnp.asarray([2.0])))
        np.testing.assert_allclose(out_a, out_f, rtol=RTOL, atol=ATOL)

    def test_jit_wrapper_matches(self):
        adj, x, mask, scale = _rand_case(8, 192, 32)
        out = masked_aggregate_jit(adj, x, mask, jnp.float32(scale))
        ref = masked_aggregate_ref(adj, x, mask, scale)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=RTOL, atol=ATOL)

    def test_custom_block_size(self):
        _check(*_rand_case(9, 96, 16), block_n=32)

    def test_empty_graph_no_edges(self):
        n, f = 64, 16
        adj = jnp.zeros((n, n), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(10), (n, f))
        out = masked_aggregate(adj, x, jnp.ones_like(x), 1.0)
        assert np.abs(np.asarray(out)).max() == 0.0

    def test_shape_mismatch_raises(self):
        adj, x, mask, scale = _rand_case(11, 32, 8)
        with pytest.raises(ValueError):
            masked_aggregate(adj[:16], x, mask, scale)
        with pytest.raises(ValueError):
            masked_aggregate(adj, x, mask[:, :4], scale)


class TestMaskedAggregateBurstStructure:
    """Burst/row-granular masks (the shapes LiGNN actually produces)."""

    def test_burst_granular_mask(self):
        # K=8 elements per burst: mask constant within aligned 8-lane groups.
        n, f, k = 128, 64, 8
        adj, x, _, _ = _rand_case(12, n, f)
        keep = (jax.random.uniform(jax.random.PRNGKey(13), (n, f // k)) >= 0.5)
        mask = jnp.repeat(keep.astype(jnp.float32), k, axis=1)
        _check(adj, x, mask, 2.0)

    def test_row_granular_mask(self):
        # DRAM-row granularity: whole vertices dropped in aligned groups of 8.
        n, f, g = 128, 32, 8
        adj, x, _, _ = _rand_case(14, n, f)
        keep = (jax.random.uniform(jax.random.PRNGKey(15), (n // g, 1)) >= 0.5)
        mask = jnp.broadcast_to(
            jnp.repeat(keep.astype(jnp.float32), g, axis=0)[:, :1], (n, f)
        )
        _check(adj, x, mask, 2.0)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=300),
    f=st.integers(min_value=1, max_value=96),
    density=st.floats(min_value=0.0, max_value=1.0),
    alpha=st.floats(min_value=0.0, max_value=0.95),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_kernel_matches_ref(n, f, density, alpha, seed):
    """Property: kernel == oracle across arbitrary shapes/densities/rates."""
    adj, x, mask, scale = _rand_case(seed, n, f, density, alpha)
    _check(adj, x, mask, scale)


@settings(max_examples=10, deadline=None)
@given(
    block=st.sampled_from([8, 16, 32, 64, 128, 256]),
    n=st.integers(min_value=1, max_value=200),
)
def test_hypothesis_block_size_invariance(block, n):
    """Property: the block size never changes the result."""
    adj, x, mask, scale = _rand_case(n, n, 24)
    a = np.asarray(masked_aggregate(adj, x, mask, scale, block_n=block))
    b = np.asarray(masked_aggregate_ref(adj, x, mask, scale))
    np.testing.assert_allclose(a, b, rtol=RTOL, atol=ATOL)
